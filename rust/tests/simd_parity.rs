//! SIMD-vs-scalar differential harness (the tier-dispatch acceptance
//! suite).
//!
//! The scalar integer kernels (`quant::act::dot_i8` and the inline
//! epilogues they feed) are the **oracle**; every runtime-dispatched
//! tier in `quant::simd` must reproduce them bit-for-bit — integer i32
//! accumulation is regrouping-invariant and every f32 epilogue is
//! shared verbatim, so equality is exact, not approximate. Enforced
//! here at four levels:
//!
//! 1. block level — `dot_block_q8`/`gemm_block_q8` per hot format, on
//!    the shared seeded kernel fuzz loop (adversarial shapes first);
//! 2. linear level — `gemm_q8` == `matvec_q8` == row shards, across
//!    tiers and batch sizes 1/2/5/8;
//! 3. padded level — `PaddedLinear::{matvec_q8,matmul_q8}` with the
//!    scratch NaN-poisoned so a lane reading past the logical row end
//!    cannot pass silently;
//! 4. engine level — full decode with dispatch forced on vs off.
//!
//! Plus dispatch-table correctness: forcing a tier and *counting* the
//! dispatched calls per tier proves the forced tier is the one that
//! actually ran (a bad feature probe cannot silently fall back), and
//! that formats without `has_q8_kernel` never touch the dispatcher.
//!
//! Tier forcing and the probe counters are process-global, so every
//! test here serializes on one lock; unavailable tiers self-skip with
//! the repo's standard skip message (under `ITQ3S_NO_SIMD=1` every
//! non-scalar tier is unavailable by design and the whole suite
//! degrades to scalar-vs-scalar — which is exactly what the CI
//! dispatch-off run asserts).

mod common;

use common::{hot_formats, prompt_tokens, quant_engine, sequential_decode};
use itq3s::model::weights::PaddedLinear;
use itq3s::model::{KvCache, ModelConfig};
use itq3s::quant::format_by_name;
use itq3s::quant::matmul::{MatvecScratch, QuantizedLinear};
use itq3s::quant::simd::{self, SimdTier};
use itq3s::util::prop::{forall_kernel_cases, heavy_tailed_tensor};
use itq3s::util::XorShift;
use std::sync::Mutex;

static DISPATCH_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    DISPATCH_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// RAII guard: follow hardware detection again when a test ends, even
/// on panic.
struct Restore;
impl Drop for Restore {
    fn drop(&mut self) {
        simd::clear_force();
    }
}

/// The non-scalar tiers this host can actually run.
fn simd_tiers() -> Vec<SimdTier> {
    [SimdTier::Avx2, SimdTier::Neon]
        .into_iter()
        .filter(|&t| simd::tier_available(t))
        .collect()
}

fn skip_no_simd(test: &str) {
    eprintln!(
        "{test}: no SIMD tier available (scalar-only host or ITQ3S_NO_SIMD set); \
         scalar==scalar holds trivially — skipping"
    );
}

#[test]
fn block_kernels_bitwise_equal_scalar_every_format_and_tier() {
    let _g = lock();
    let _r = Restore;
    let tiers = simd_tiers();
    if tiers.is_empty() {
        skip_no_simd("block_kernels_bitwise_equal_scalar_every_format_and_tier");
        return;
    }
    for name in hot_formats() {
        let be = format_by_name(name).unwrap().block_elems();
        let prop = format!("simd dot/gemm == scalar blocks [{name}]");
        forall_kernel_cases(&prop, be, 12, |case, w, rows| {
            let fmt = format_by_name(name).unwrap();
            let mut bytes = Vec::new();
            fmt.quantize_block(case, w, &mut bytes);
            let cols = rows.len();
            let flat: Vec<f32> = rows.concat();
            let mut batch = itq3s::quant::act::QuantizedBatch::new();
            batch.quantize(&flat, cols, be);
            let bb = batch.block_at(0);
            // Scalar oracle first.
            assert!(simd::try_force(SimdTier::Scalar));
            let mut tmp = Vec::new();
            let dots_ref: Vec<f32> = (0..cols)
                .map(|t| fmt.dot_block_q8(case, &bytes, bb.col(t), &mut tmp))
                .collect();
            let mut y_ref = vec![0.0f32; cols];
            fmt.gemm_block_q8(case, &bytes, bb, &mut y_ref, &mut tmp);
            for &tier in &tiers {
                assert!(simd::try_force(tier), "{tier:?} vanished mid-test");
                for t in 0..cols {
                    let d = fmt.dot_block_q8(case, &bytes, bb.col(t), &mut tmp);
                    assert_eq!(
                        d.to_bits(),
                        dots_ref[t].to_bits(),
                        "{name} {tier:?} case {case} col {t}: {d} vs {}",
                        dots_ref[t]
                    );
                }
                let mut y = vec![0.0f32; cols];
                fmt.gemm_block_q8(case, &bytes, bb, &mut y, &mut tmp);
                for t in 0..cols {
                    assert_eq!(
                        y[t].to_bits(),
                        y_ref[t].to_bits(),
                        "{name} {tier:?} case {case} gemm col {t}: {} vs {}",
                        y[t],
                        y_ref[t]
                    );
                }
            }
            // Back to the oracle for the next fuzz case's reference.
            assert!(simd::try_force(SimdTier::Scalar));
        });
    }
}

#[test]
fn linear_gemm_and_matvec_bitwise_equal_across_tiers() {
    let _g = lock();
    let _r = Restore;
    let tiers = simd_tiers();
    if tiers.is_empty() {
        skip_no_simd("linear_gemm_and_matvec_bitwise_equal_across_tiers");
        return;
    }
    let w = heavy_tailed_tensor(37, 512, 71, 5.0); // odd rows: uneven shards
    for name in hot_formats() {
        let lin = QuantizedLinear::new(format_by_name(name).unwrap(), &w);
        let mut scratch = MatvecScratch::new();
        let mut rng = XorShift::new(72);
        for batch in [1usize, 2, 5, 8] {
            let x: Vec<f32> = (0..batch * 512).map(|_| rng.next_f32() - 0.5).collect();
            assert!(simd::try_force(SimdTier::Scalar));
            let mut y_ref = vec![0.0f32; batch * 37];
            lin.gemm_q8(&x, batch, &mut y_ref, &mut scratch, 1);
            for &tier in &tiers {
                assert!(simd::try_force(tier));
                // Batched GEMM: every batch size, bitwise vs scalar.
                let mut y = vec![0.0f32; batch * 37];
                lin.gemm_q8(&x, batch, &mut y, &mut scratch, 1);
                assert_eq!(y, y_ref, "{name} {tier:?} gemm batch={batch}");
                // Sequential matvec rows == the same GEMM rows (the
                // linear-level contract), still on the SIMD tier.
                for t in 0..batch {
                    let mut yt = vec![0.0f32; 37];
                    lin.matvec_q8(&x[t * 512..(t + 1) * 512], &mut yt, &mut scratch, 1);
                    assert_eq!(
                        &y[t * 37..(t + 1) * 37],
                        &yt[..],
                        "{name} {tier:?} batch={batch} row {t}"
                    );
                }
                // Row sharding stays bit-identical on SIMD tiers too.
                for shards in [3usize, 8] {
                    let mut ys = vec![0.0f32; batch * 37];
                    lin.gemm_q8(&x, batch, &mut ys, &mut scratch, shards);
                    assert_eq!(ys, y_ref, "{name} {tier:?} batch={batch} shards={shards}");
                }
            }
        }
    }
}

#[test]
fn padded_linears_with_poisoned_scratch_bitwise_equal_scalar() {
    let _g = lock();
    let _r = Restore;
    // Tail-row guard: cols % block != 0 forces the padded staging path;
    // the scratch (including the padding region) is NaN-poisoned before
    // every call, so a SIMD lane reading past the logical row end drags
    // NaN into y and fails the finite/bitwise asserts. Runs even
    // scalar-only: the poison checks are meaningful on every tier.
    let tiers = simd_tiers();
    let mut rng = XorShift::new(81);
    for (name, cols) in [("itq3_s", 300usize), ("q8_0", 260), ("q4_k_m", 300), ("iq3_s", 300)] {
        let w = heavy_tailed_tensor(9, cols, 82, 5.0);
        let pl = PaddedLinear::new(format_by_name(name).unwrap(), &w);
        let mut scratch = MatvecScratch::new();
        let batch = 5usize;
        let x: Vec<f32> = (0..batch * cols).map(|_| rng.next_f32() - 0.5).collect();
        assert!(simd::try_force(SimdTier::Scalar));
        let mut y_ref = vec![0.0f32; 9];
        scratch.poison();
        pl.matvec_q8(&x[..cols], &mut y_ref, &mut scratch);
        assert!(y_ref.iter().all(|v| v.is_finite()), "{name}: scalar poison leak");
        let mut yb_ref = vec![0.0f32; batch * 9];
        scratch.poison();
        pl.matmul_q8(&x, batch, &mut yb_ref, &mut scratch);
        assert!(yb_ref.iter().all(|v| v.is_finite()));
        for &tier in &tiers {
            assert!(simd::try_force(tier));
            let mut y = vec![0.0f32; 9];
            scratch.poison();
            pl.matvec_q8(&x[..cols], &mut y, &mut scratch);
            assert_eq!(y, y_ref, "{name} {tier:?} padded matvec");
            let mut yb = vec![0.0f32; batch * 9];
            scratch.poison();
            pl.matmul_q8(&x, batch, &mut yb, &mut scratch);
            assert_eq!(yb, yb_ref, "{name} {tier:?} padded matmul");
        }
    }
    if tiers.is_empty() {
        skip_no_simd("padded_linears_with_poisoned_scratch (SIMD legs)");
    }
}

#[test]
fn engine_decode_bitwise_identical_dispatch_on_vs_off() {
    let _g = lock();
    let _r = Restore;
    let tiers = simd_tiers();
    if tiers.is_empty() {
        skip_no_simd("engine_decode_bitwise_identical_dispatch_on_vs_off");
        return;
    }
    let prompt = prompt_tokens(12, 3);
    let forced: Vec<u32> = (0..6u32).map(|i| (i * 29 + 7) % 256).collect();
    for name in hot_formats() {
        let eng = quant_engine(name, 91);
        assert!(simd::try_force(SimdTier::Scalar));
        let mut kv = KvCache::new(&ModelConfig::test());
        let logits_ref = sequential_decode(&eng, &mut kv, &prompt, &forced);
        for &tier in &tiers {
            assert!(simd::try_force(tier));
            let mut kv2 = KvCache::new(&ModelConfig::test());
            let logits = sequential_decode(&eng, &mut kv2, &prompt, &forced);
            assert_eq!(logits.len(), logits_ref.len());
            for (step, (a, b)) in logits.iter().zip(&logits_ref).enumerate() {
                assert_eq!(a.len(), b.len());
                for (i, (x, y)) in a.iter().zip(b).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{name} {tier:?} step {step} logit {i}: {x} vs {y}"
                    );
                }
            }
        }
    }
}

#[test]
fn forced_tier_is_what_actually_runs_and_matches_kernel_capability() {
    let _g = lock();
    let _r = Restore;
    let w = heavy_tailed_tensor(4, 512, 101, 5.0);
    let mut rng = XorShift::new(102);
    let x: Vec<f32> = (0..512).map(|_| rng.next_f32() - 0.5).collect();
    for tier in SimdTier::ALL {
        if !simd::try_force(tier) {
            assert!(
                !simd::tier_available(tier),
                "{tier:?}: try_force failed on an available tier"
            );
            eprintln!("tier {tier:?} unavailable on this host; skipping its forced run");
            continue;
        }
        assert_eq!(simd::active_tier(), tier);
        // Specialized formats: the forced tier — and only that tier —
        // actually runs, so a bad feature probe cannot silently fall
        // back to scalar while claiming SIMD (or vice versa).
        for name in hot_formats() {
            let fmt = format_by_name(name).unwrap();
            assert!(fmt.has_q8_kernel(), "{name} listed hot without a kernel");
            let lin = QuantizedLinear::new(fmt, &w);
            let mut scratch = MatvecScratch::new();
            let mut y = vec![0.0f32; 4];
            simd::probe_begin();
            lin.matvec_q8(&x, &mut y, &mut scratch, 1);
            let counts = simd::probe_end();
            assert!(
                counts[tier.index()] > 0,
                "{name}: forced {tier:?} never dispatched (counts {counts:?})"
            );
            for other in SimdTier::ALL {
                if other != tier {
                    assert_eq!(
                        counts[other.index()],
                        0,
                        "{name}: {other:?} ran while {tier:?} was forced (counts {counts:?})"
                    );
                }
            }
        }
        // Generic-fallback formats must never touch the dispatcher:
        // kernel selection (has_q8_kernel) and dispatch agree.
        for name in ["fp16", "iq4_xs", "quip3", "itq3_s_sub"] {
            let fmt = format_by_name(name).unwrap();
            assert!(!fmt.has_q8_kernel(), "{name} gained a kernel; update this test");
            let lin = QuantizedLinear::new(fmt, &w);
            let mut scratch = MatvecScratch::new();
            let mut y = vec![0.0f32; 4];
            simd::probe_begin();
            lin.matvec_q8(&x, &mut y, &mut scratch, 1);
            let counts = simd::probe_end();
            assert_eq!(
                counts,
                [0, 0, 0],
                "{name}: generic fallback reached the SIMD dispatcher"
            );
        }
    }
    // The CLI/env paths land on scalar.
    simd::clear_force();
    simd::set_enabled(false);
    assert_eq!(simd::active_tier(), SimdTier::Scalar);
    simd::set_enabled(true);
    assert_eq!(simd::active_tier(), simd::detected_tier());
}
