//! Shared builders for the integration tests: tiny deterministic models,
//! engines over every hot format, and KV plumbing helpers. Each test
//! binary compiles this module independently and uses a subset of it.
#![allow(dead_code)]

use itq3s::model::native::Engine;
use itq3s::model::{DenseModel, KvCache, KvStore, ModelConfig, NativeEngine, QuantizedModel};
use itq3s::quant::format_by_name;

/// The serving formats with hand-specialized W3A8/GEMM kernels —
/// derived from the `Format` capability itself so a format that gains
/// a kernel is picked up by the batched-decode harness automatically.
pub fn hot_formats() -> Vec<&'static str> {
    itq3s::quant::TABLE1_FORMATS
        .iter()
        .copied()
        .filter(|name| format_by_name(name).unwrap().has_q8_kernel())
        .collect()
}

/// Deterministic heavy-tailed tiny model (same architecture the trained
/// checkpoint uses; seeds keep every run bit-reproducible).
pub fn dense_model(seed: u64) -> DenseModel {
    DenseModel::random(&ModelConfig::test(), seed, Some(5.0))
}

pub fn dense_engine(seed: u64) -> NativeEngine {
    NativeEngine::dense(dense_model(seed))
}

/// Quantize the seed model into `fmt` and wrap it in a native engine.
pub fn quant_engine(fmt: &str, seed: u64) -> NativeEngine {
    NativeEngine::quantized(QuantizedModel::quantize(
        &dense_model(seed),
        format_by_name(fmt).unwrap_or_else(|| panic!("unknown format {fmt}")),
    ))
}

/// Does the trained-checkpoint fixture exist (`make artifacts` has run)?
pub fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/model_fp32.iguf").exists()
}

/// Dense model from the trained checkpoint when artifacts exist, else
/// the deterministic random heavy-tailed test model seeded with `seed`
/// — the shared fixture the end-to-end suites (`serving.rs`,
/// `w3a8.rs`) build their engines from.
pub fn dense_fixture_or_random(seed: u64) -> DenseModel {
    if have_artifacts() {
        itq3s::gguf::load_dense(std::path::Path::new("artifacts/model_fp32.iguf")).unwrap()
    } else {
        eprintln!("artifacts/ not built; using a random heavy-tailed model");
        dense_model(seed)
    }
}

/// The serving fixture: the checkpoint (or its random fallback)
/// quantized into `fmt` behind a native engine.
pub fn quant_fixture(fmt: &str, seed: u64) -> NativeEngine {
    NativeEngine::quantized(QuantizedModel::quantize(
        &dense_fixture_or_random(seed),
        format_by_name(fmt).unwrap_or_else(|| panic!("unknown format {fmt}")),
    ))
}

/// Deterministic pseudo-prompt of `len` tokens (distinct per `salt`).
pub fn prompt_tokens(len: usize, salt: u32) -> Vec<u32> {
    (0..len as u32).map(|i| (i * 31 + salt * 17 + 1) % 256).collect()
}

/// Prefill `prompt` and then teacher-force `forced` through
/// [`Engine::decode_step`], returning the logits of every decode step —
/// the sequential reference the batched paths are differentially tested
/// against.
pub fn sequential_decode(
    eng: &dyn Engine,
    store: &mut dyn KvStore,
    prompt: &[u32],
    forced: &[u32],
) -> Vec<Vec<f32>> {
    eng.prefill(store, prompt);
    forced.iter().map(|&t| eng.decode_step(store, t)).collect()
}

/// A [`KvStore`] that forwards everything to `primary` while recording
/// every written K/V row into a dense f32 `shadow` — so a lossy
/// (quantized) primary can be compared row-by-row against exactly what
/// the engine wrote into it.
pub struct TeeStore<'a> {
    pub primary: &'a mut dyn KvStore,
    pub shadow: KvCache,
}

impl<'a> TeeStore<'a> {
    pub fn new(primary: &'a mut dyn KvStore, cfg: &ModelConfig) -> Self {
        TeeStore { primary, shadow: KvCache::new(cfg) }
    }
}

impl KvStore for TeeStore<'_> {
    fn len(&self) -> usize {
        self.primary.len()
    }

    fn capacity(&self) -> usize {
        self.primary.capacity()
    }

    fn tokens(&self) -> &[u32] {
        self.primary.tokens()
    }

    fn push_token(&mut self, t: u32) {
        self.shadow.tokens.push(t);
        self.primary.push_token(t);
    }

    fn k_at(&mut self, layer: usize, pos: usize) -> &[f32] {
        self.primary.k_at(layer, pos)
    }

    fn v_at(&mut self, layer: usize, pos: usize) -> &[f32] {
        self.primary.v_at(layer, pos)
    }

    fn write_kv(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.shadow.write_kv(layer, pos, k, v);
        self.primary.write_kv(layer, pos, k, v);
    }

    fn truncate(&mut self, len: usize) {
        self.shadow.tokens.truncate(len);
        self.primary.truncate(len);
    }
}
