//! Differential harness for the fused batched decode path: for every
//! format with a Q8 kernel (plus the dense, f32-baseline and
//! generic-fallback configurations), stepping B sequences through
//! `Engine::decode_batch` must be **bit-identical** to stepping each
//! sequence alone through `decode_step` — across batch sizes
//! {1, 2, 5, 8}, through dense and paged KV stores, and for ragged
//! batches whose sequences join and leave mid-decode.

mod common;

use common::{dense_engine, hot_formats, prompt_tokens, quant_engine, sequential_decode};
use itq3s::kvpaged::{KvQuant, PagedKvPool};
use itq3s::model::native::Engine;
use itq3s::model::{KvCache, KvStore, ModelConfig, NativeEngine, QuantizedModel, StoreBatch};

/// Forced decode streams keep the comparison teacher-forced (sampling
/// would hide a divergence behind identical argmaxes).
fn forced_tokens(rounds: usize, salt: u32) -> Vec<u32> {
    (0..rounds as u32).map(|i| (i * 53 + salt * 7 + 11) % 256).collect()
}

/// Run `rounds` fused decode rounds over freshly prefilled dense caches
/// and compare every step of every sequence against the sequential
/// reference, bit for bit.
fn assert_batched_matches_sequential(eng: &NativeEngine, label: &str, batch: usize) {
    let cfg = ModelConfig::test();
    let rounds = 4;
    // Ragged prompts: lengths vary per sequence.
    let prompts: Vec<Vec<u32>> =
        (0..batch).map(|s| prompt_tokens(2 + (s * 3) % 7, s as u32)).collect();
    let forced: Vec<Vec<u32>> =
        (0..batch).map(|s| forced_tokens(rounds, s as u32)).collect();

    // Sequential reference, one isolated run per sequence.
    let mut want: Vec<Vec<Vec<f32>>> = Vec::new();
    for s in 0..batch {
        let mut c = KvCache::new(&cfg);
        want.push(sequential_decode(eng, &mut c, &prompts[s], &forced[s]));
    }

    // Batched run: same prefills, then fused rounds.
    let mut caches: Vec<KvCache> = prompts
        .iter()
        .map(|p| {
            let mut c = KvCache::new(&cfg);
            eng.prefill(&mut c, p);
            c
        })
        .collect();
    for r in 0..rounds {
        let toks: Vec<u32> = (0..batch).map(|s| forced[s][r]).collect();
        let stores: Vec<&mut dyn KvStore> =
            caches.iter_mut().map(|c| c as &mut dyn KvStore).collect();
        let mut kv = StoreBatch { stores };
        let got = eng.decode_batch(&mut kv, &toks);
        assert_eq!(got.len(), batch);
        for (s, g) in got.iter().enumerate() {
            assert_eq!(
                g, &want[s][r],
                "{label}: batch={batch} seq={s} round={r} diverged from sequential"
            );
        }
    }
    // KV state advanced identically (token history check).
    for (s, c) in caches.iter().enumerate() {
        assert_eq!(c.len(), prompts[s].len() + rounds, "{label}: seq {s} history");
    }
}

#[test]
fn batched_decode_bit_identical_all_hot_formats() {
    let hot = hot_formats();
    assert!(hot.len() >= 4, "expected the four specialized formats, got {hot:?}");
    for fmt in hot {
        let eng = quant_engine(fmt, 23);
        for batch in [1usize, 2, 5, 8] {
            assert_batched_matches_sequential(&eng, fmt, batch);
        }
    }
}

#[test]
fn batched_decode_bit_identical_dense_and_fallback_configs() {
    // Dense weights (no quantization at all)...
    let dense = dense_engine(29);
    // ...the f32 comparison baseline (integer path disabled)...
    let f32_path = NativeEngine::quantized(QuantizedModel::quantize(
        &common::dense_model(29),
        itq3s::quant::format_by_name("itq3_s").unwrap(),
    ))
    .with_act_quant(false);
    // ...and a format without a specialized Q8 kernel (routes down the
    // row-sharded f32 path even with act_quant on).
    let no_kernel = quant_engine("iq4_xs", 29);
    for (label, eng) in
        [("dense", &dense), ("act_quant_off", &f32_path), ("iq4_xs", &no_kernel)]
    {
        for batch in [1usize, 2, 5] {
            assert_batched_matches_sequential(eng, label, batch);
        }
    }
}

#[test]
fn ragged_batches_join_and_leave_mid_decode() {
    // Sequences enter the batch at different rounds (fresh prefill) and
    // retire at different rounds — the shape a continuous-batching
    // coordinator actually produces. Every step of every sequence must
    // still equal its isolated sequential run, bit for bit.
    let cfg = ModelConfig::test();
    let eng = quant_engine("itq3_s", 31);
    let prompts: Vec<Vec<u32>> = [3usize, 5, 2, 7, 4]
        .iter()
        .enumerate()
        .map(|(s, &len)| prompt_tokens(len, s as u32))
        .collect();
    // Round membership (ascending indices). Batch sizes sweep 1→2→5→4→2→1.
    let schedule: [&[usize]; 7] = [
        &[0],
        &[0, 1],
        &[0, 1, 2, 3, 4],
        &[0, 1, 2, 3, 4],
        &[0, 2, 3, 4],
        &[2, 4],
        &[2],
    ];
    let steps_of = |s: usize| schedule.iter().filter(|m| m.contains(&s)).count();
    let forced: Vec<Vec<u32>> =
        (0..5).map(|s| forced_tokens(steps_of(s), s as u32)).collect();

    // Isolated sequential references.
    let mut want: Vec<Vec<Vec<f32>>> = Vec::new();
    for s in 0..5 {
        let mut c = KvCache::new(&cfg);
        want.push(sequential_decode(&eng, &mut c, &prompts[s], &forced[s]));
    }

    let mut caches: Vec<Option<KvCache>> = (0..5).map(|_| None).collect();
    let mut step: [usize; 5] = [0; 5];
    for (round, members) in schedule.iter().enumerate() {
        // Join: prefill newcomers.
        for &s in members.iter() {
            if caches[s].is_none() {
                let mut c = KvCache::new(&cfg);
                eng.prefill(&mut c, &prompts[s]);
                caches[s] = Some(c);
            }
        }
        let toks: Vec<u32> = members.iter().map(|&s| forced[s][step[s]]).collect();
        let stores: Vec<&mut dyn KvStore> = caches
            .iter_mut()
            .enumerate()
            .filter(|(i, c)| members.contains(i) && c.is_some())
            .map(|(_, c)| c.as_mut().unwrap() as &mut dyn KvStore)
            .collect();
        assert_eq!(stores.len(), members.len());
        let mut kv = StoreBatch { stores };
        let got = eng.decode_batch(&mut kv, &toks);
        for (j, &s) in members.iter().enumerate() {
            assert_eq!(
                &got[j], &want[s][step[s]],
                "round {round}: seq {s} (step {}) diverged",
                step[s]
            );
            step[s] += 1;
        }
        // Leave: drop retired members' caches (mid-schedule retirement).
        for (s, c) in caches.iter_mut().enumerate() {
            if c.is_some() && !schedule[round + 1..].iter().any(|m| m.contains(&s)) {
                *c = None;
            }
        }
    }
    for s in 0..5 {
        assert_eq!(step[s], steps_of(s), "seq {s} stepped every scheduled round");
    }
}

#[test]
fn batched_decode_through_paged_pool_is_bit_identical() {
    // The coordinator's actual store: several sequences of one paged
    // f32 pool, batched through `PagedKvPool::batch_view`, against
    // isolated dense-cache sequential runs.
    let cfg = ModelConfig::test();
    let eng = quant_engine("q8_0", 37);
    let rounds = 5;
    for &bt in &[4usize, 16] {
        let batch = 5;
        let prompts: Vec<Vec<u32>> =
            (0..batch).map(|s| prompt_tokens(3 + s, s as u32)).collect();
        let forced: Vec<Vec<u32>> =
            (0..batch).map(|s| forced_tokens(rounds, s as u32)).collect();
        let mut want: Vec<Vec<Vec<f32>>> = Vec::new();
        for s in 0..batch {
            let mut c = KvCache::new(&cfg);
            want.push(sequential_decode(&eng, &mut c, &prompts[s], &forced[s]));
        }
        let mut pool = PagedKvPool::new(&cfg, bt, KvQuant::F32, 64 << 20);
        let ids: Vec<_> = (0..batch)
            .map(|s| {
                let id = pool.create_seq();
                eng.prefill(&mut pool.seq_view(id), &prompts[s]);
                id
            })
            .collect();
        for r in 0..rounds {
            let toks: Vec<u32> = (0..batch).map(|s| forced[s][r]).collect();
            let got = eng.decode_batch(&mut pool.batch_view(&ids), &toks);
            for (s, g) in got.iter().enumerate() {
                assert_eq!(g, &want[s][r], "bt={bt} seq={s} round={r} diverged");
            }
        }
        for id in ids {
            pool.release_seq(id);
        }
        assert_eq!(pool.in_use_blocks(), 0);
    }
}

#[test]
fn coordinator_fused_rounds_match_solo_runs() {
    // End-to-end: greedy generations through a coordinator decoding 3
    // sequences per fused round must equal the same requests run alone.
    // Overlap is made deterministic the way the PR-2 occupancy test
    // does it: a long request is submitted first and its followers only
    // after its first token arrives — it then has ≥ 23 decode rounds
    // left, so the followers provably share fused rounds with it.
    use itq3s::coordinator::{Coordinator, CoordinatorConfig, Event, GenRequest};
    let prompts = ["the archive of ", "rowan fixed the ", "in the year "];
    let max_toks = |i: usize| if i == 0 { 24 } else { 10 };
    let run = |max_batch: usize, prompt: &str, max_new: usize| {
        let coord = Coordinator::new(
            Box::new(quant_engine("itq3_s", 41)),
            CoordinatorConfig { max_batch, prefill_chunk: 8, ..Default::default() },
        );
        let (text, _) = coord.generate_collect(GenRequest {
            prompt: prompt.into(),
            max_new_tokens: max_new,
            ..Default::default()
        });
        coord.shutdown();
        text
    };
    let solo: Vec<String> =
        prompts.iter().enumerate().map(|(i, p)| run(1, p, max_toks(i))).collect();

    let coord = Coordinator::new(
        Box::new(quant_engine("itq3_s", 41)),
        CoordinatorConfig { max_batch: 3, prefill_chunk: 8, ..Default::default() },
    );
    let rx0 = coord.generate(GenRequest {
        prompt: prompts[0].into(),
        max_new_tokens: max_toks(0),
        ..Default::default()
    });
    // Wait for the long request's first token before admitting rivals.
    let mut text0 = String::new();
    for ev in rx0.iter() {
        if let Event::Token { text: t, .. } = ev {
            text0.push_str(&t);
            break;
        }
    }
    let followers: Vec<_> = (1..3)
        .map(|i| {
            coord.generate(GenRequest {
                prompt: prompts[i].into(),
                max_new_tokens: max_toks(i),
                ..Default::default()
            })
        })
        .collect();
    for (i, rx) in followers.into_iter().enumerate() {
        let mut text = String::new();
        for ev in rx.iter() {
            match ev {
                Event::Token { text: t, .. } => text.push_str(&t),
                Event::Done { .. } => break,
                _ => {}
            }
        }
        assert_eq!(text, solo[i + 1], "follower {} diverged under fused batching", i + 1);
    }
    for ev in rx0.iter() {
        match ev {
            Event::Token { text: t, .. } => text0.push_str(&t),
            Event::Done { .. } => break,
            _ => {}
        }
    }
    assert_eq!(text0, solo[0], "long request diverged under fused batching");
    let stats = coord.stats().unwrap();
    assert!(
        stats.get("decode_batch_size_max").unwrap().as_f64().unwrap() >= 2.0,
        "fused rounds must actually have batched"
    );
    coord.shutdown();
}
