//! Microbenchmarks of the hot-path kernels (§Perf evidence):
//! FWHT radix-2 vs radix-4, block dequant, fused vs naive matvec.
use itq3s::bench::harness::bench;
use itq3s::quant::{format_by_name, matmul::QuantizedLinear};
use itq3s::tensor::Tensor;
use itq3s::util::XorShift;

fn main() {
    let mut rng = XorShift::new(1);

    // --- FWHT variants ----------------------------------------------
    let mut block = [0.0f32; 256];
    for (i, x) in block.iter_mut().enumerate() {
        *x = (i as f32).sin();
    }
    let iters = 50_000;
    let r2 = bench("fwht radix-2", 2, 5, || {
        let mut v = block.to_vec();
        for _ in 0..iters {
            itq3s::fwht::fwht_inplace(std::hint::black_box(&mut v));
        }
    });
    let r4 = bench("fwht_256 radix-4", 2, 5, || {
        let mut v = block;
        for _ in 0..iters {
            itq3s::fwht::fwht_256(std::hint::black_box(&mut v));
        }
    });
    println!(
        "fwht-256:   radix-2 {:>8.1} ns/block   radix-4 {:>8.1} ns/block   ({:.2}x)",
        r2.mean_s / iters as f64 * 1e9,
        r4.mean_s / iters as f64 * 1e9,
        r2.mean_s / r4.mean_s
    );

    // --- fused vs naive quantized matvec ------------------------------
    let w = Tensor::randn(vec![256, 1024], 0.02, &mut rng);
    let x: Vec<f32> = (0..1024).map(|_| rng.next_f32() - 0.5).collect();
    for name in ["itq3_s", "iq3_s", "q4_k_m", "q8_0"] {
        let lin = QuantizedLinear::new(format_by_name(name).unwrap(), &w);
        let mut y = vec![0.0f32; 256];
        let rf = bench("fused", 3, 10, || {
            lin.matvec(std::hint::black_box(&x), &mut y);
        });
        let rn = bench("naive", 3, 10, || {
            lin.matvec_naive(std::hint::black_box(&x), &mut y);
        });
        let macs = 256.0 * 1024.0;
        println!(
            "matvec {name:<8} fused {:>7.1} us ({:>6.2} GMAC/s)   naive {:>7.1} us   speedup {:.2}x",
            rf.mean_s * 1e6,
            macs / rf.mean_s / 1e9,
            rn.mean_s * 1e6,
            rn.mean_s / rf.mean_s
        );
    }

    // --- dense reference ------------------------------------------------
    let mut y = vec![0.0f32; 256];
    let rd = bench("dense", 3, 10, || {
        y.fill(0.0);
        itq3s::tensor::matvec_accum(std::hint::black_box(&w), &x, &mut y);
    });
    println!(
        "matvec dense-f32 {:>7.1} us ({:>6.2} GMAC/s)",
        rd.mean_s * 1e6,
        256.0 * 1024.0 / rd.mean_s / 1e9
    );
}
