//! Microbenchmarks of the hot-path kernels (§Perf evidence):
//! FWHT radix-2 vs radix-4, fused-f32 vs naive vs W3A8-integer matvec,
//! and the row-sharded thread sweep. Writes `BENCH_matvec.json` next to
//! the working directory so EXPERIMENTS.md §Perf has a machine-readable
//! trajectory across PRs.
use itq3s::bench::harness::bench;
use itq3s::quant::format_by_name;
use itq3s::quant::matmul::{MatvecScratch, QuantizedLinear};
use itq3s::quant::simd;
use itq3s::tensor::Tensor;
use itq3s::util::json::Json;
use itq3s::util::XorShift;
use std::collections::BTreeMap;

fn main() {
    let mut rng = XorShift::new(1);
    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    println!("simd tier: {}", simd::active_tier().name());
    report.insert("simd_tier".to_string(), Json::str(simd::active_tier().name()));

    // --- FWHT variants ----------------------------------------------
    let mut block = [0.0f32; 256];
    for (i, x) in block.iter_mut().enumerate() {
        *x = (i as f32).sin();
    }
    let iters = 50_000;
    let r2 = bench("fwht radix-2", 2, 5, || {
        let mut v = block.to_vec();
        for _ in 0..iters {
            itq3s::fwht::fwht_inplace(std::hint::black_box(&mut v));
        }
    });
    let r4 = bench("fwht_256 radix-4", 2, 5, || {
        let mut v = block;
        for _ in 0..iters {
            itq3s::fwht::fwht_256(std::hint::black_box(&mut v));
        }
    });
    println!(
        "fwht-256:   radix-2 {:>8.1} ns/block   radix-4 {:>8.1} ns/block   ({:.2}x)",
        r2.mean_s / iters as f64 * 1e9,
        r4.mean_s / iters as f64 * 1e9,
        r2.mean_s / r4.mean_s
    );

    // --- fused f32 vs naive vs W3A8 integer matvec --------------------
    let rows = 256usize;
    let cols = 1024usize;
    let w = Tensor::randn(vec![rows, cols], 0.02, &mut rng);
    let x: Vec<f32> = (0..cols).map(|_| rng.next_f32() - 0.5).collect();
    let macs = (rows * cols) as f64;
    let mut formats_json: BTreeMap<String, Json> = BTreeMap::new();
    for name in ["itq3_s", "iq3_s", "q4_k_m", "q8_0"] {
        let lin = QuantizedLinear::new(format_by_name(name).unwrap(), &w);
        let mut y = vec![0.0f32; rows];
        let mut scratch = MatvecScratch::new();
        let rf = bench("fused", 3, 10, || {
            lin.matvec(std::hint::black_box(&x), &mut y);
        });
        let rq = bench("q8", 3, 10, || {
            lin.matvec_q8(std::hint::black_box(&x), &mut y, &mut scratch, 1);
        });
        // Same kernel with dispatch pinned to the scalar oracle — the
        // SIMD speedup is q8_scalar/q8 on identical inputs (bit-identical
        // outputs, so the ratio is pure throughput).
        simd::set_enabled(false);
        let rqs = bench("q8 scalar", 3, 10, || {
            lin.matvec_q8(std::hint::black_box(&x), &mut y, &mut scratch, 1);
        });
        simd::set_enabled(true);
        let rn = bench("naive", 3, 10, || {
            lin.matvec_naive(std::hint::black_box(&x), &mut y);
        });
        println!(
            "matvec {name:<8} f32 {:>7.1} us ({:>6.2} GMAC/s)   q8 {:>7.1} us ({:>6.2} GMAC/s)   q8-scalar {:>7.1} us   naive {:>7.1} us   q8-vs-f32 {:.2}x   simd {:.2}x",
            rf.mean_s * 1e6,
            macs / rf.mean_s / 1e9,
            rq.mean_s * 1e6,
            macs / rq.mean_s / 1e9,
            rqs.mean_s * 1e6,
            rn.mean_s * 1e6,
            rf.mean_s / rq.mean_s,
            rqs.mean_s / rq.mean_s
        );
        formats_json.insert(
            name.to_string(),
            Json::obj(vec![
                ("fused_f32_us", Json::num(rf.mean_s * 1e6)),
                ("q8_us", Json::num(rq.mean_s * 1e6)),
                ("q8_scalar_us", Json::num(rqs.mean_s * 1e6)),
                ("naive_us", Json::num(rn.mean_s * 1e6)),
                ("q8_speedup_vs_f32", Json::num(rf.mean_s / rq.mean_s)),
                ("simd_speedup", Json::num(rqs.mean_s / rq.mean_s)),
                ("fused_speedup_vs_naive", Json::num(rn.mean_s / rf.mean_s)),
            ]),
        );
    }
    report.insert(
        "small_layer".to_string(),
        Json::obj(vec![
            ("rows", Json::num(rows as f64)),
            ("cols", Json::num(cols as f64)),
            ("formats", Json::Obj(formats_json)),
        ]),
    );

    // --- dense reference ------------------------------------------------
    let mut y = vec![0.0f32; rows];
    let rd = bench("dense", 3, 10, || {
        y.fill(0.0);
        itq3s::tensor::matvec_accum(std::hint::black_box(&w), &x, &mut y);
    });
    println!(
        "matvec dense-f32 {:>7.1} us ({:>6.2} GMAC/s)",
        rd.mean_s * 1e6,
        macs / rd.mean_s / 1e9
    );

    // --- row-sharded thread sweep (serving-size itq3_s layer) -----------
    // 2048 x 4096 ≈ a LLaMA-class attention projection; one matvec per
    // decoded token, so 1/mean_s is a tokens/sec proxy for this layer.
    let srows = 2048usize;
    let scols = 4096usize;
    let wide = Tensor::randn(vec![srows, scols], 0.02, &mut rng);
    let lin = QuantizedLinear::new(format_by_name("itq3_s").unwrap(), &wide);
    let xw: Vec<f32> = (0..scols).map(|_| rng.next_f32() - 0.5).collect();
    let mut yw = vec![0.0f32; srows];
    let mut scratch = MatvecScratch::new();
    let smacs = (srows * scols) as f64;
    let mut sweep_json: BTreeMap<String, Json> = BTreeMap::new();
    let mut t1_mean = 0.0f64;
    let mut t4_speedup = 0.0f64;
    for &threads in &[1usize, 2, 4, 8] {
        let r = bench("q8 sweep", 2, 8, || {
            lin.matvec_q8(std::hint::black_box(&xw), &mut yw, &mut scratch, threads);
        });
        if threads == 1 {
            t1_mean = r.mean_s;
        }
        if threads == 4 {
            t4_speedup = t1_mean / r.mean_s;
        }
        println!(
            "matvec itq3_s q8 {srows}x{scols} {threads}t: {:>8.1} us ({:>6.2} GMAC/s, {:>7.1} matvec/s, {:.2}x vs 1t)",
            r.mean_s * 1e6,
            smacs / r.mean_s / 1e9,
            1.0 / r.mean_s,
            t1_mean / r.mean_s
        );
        sweep_json.insert(
            threads.to_string(),
            Json::obj(vec![
                ("q8_us", Json::num(r.mean_s * 1e6)),
                ("tokens_per_s_proxy", Json::num(1.0 / r.mean_s)),
                ("speedup_vs_1t", Json::num(t1_mean / r.mean_s)),
            ]),
        );
    }
    // f32 fused single-thread baseline on the same layer, for the
    // q8-vs-f32 acceptance ratio at serving size.
    let rf_wide = bench("f32 wide", 2, 8, || {
        lin.matvec(std::hint::black_box(&xw), &mut yw);
    });
    println!(
        "matvec itq3_s f32 {srows}x{scols} 1t: {:>8.1} us   q8-vs-f32 {:.2}x   4t-vs-1t {:.2}x",
        rf_wide.mean_s * 1e6,
        rf_wide.mean_s / t1_mean,
        t4_speedup
    );
    report.insert(
        "thread_sweep".to_string(),
        Json::obj(vec![
            ("rows", Json::num(srows as f64)),
            ("cols", Json::num(scols as f64)),
            ("format", Json::str("itq3_s")),
            ("fused_f32_1t_us", Json::num(rf_wide.mean_s * 1e6)),
            ("q8_speedup_vs_f32_1t", Json::num(rf_wide.mean_s / t1_mean)),
            ("q8_speedup_4t_vs_1t", Json::num(t4_speedup)),
            ("threads", Json::Obj(sweep_json)),
        ]),
    );

    let out = Json::Obj(report).to_string();
    match std::fs::write("BENCH_matvec.json", &out) {
        Ok(()) => println!("wrote BENCH_matvec.json"),
        Err(e) => eprintln!("could not write BENCH_matvec.json: {e}"),
    }
}
