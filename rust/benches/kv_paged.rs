//! Paged-KV decode-step microbench: dense `KvCache` vs paged f32 vs
//! paged Q8 stores at a serving-ish context depth, across block sizes,
//! plus the capacity side of the trade (tokens per byte budget). Writes
//! `BENCH_kv.json` so EXPERIMENTS.md §KV has a machine-readable
//! trajectory across PRs.

use itq3s::bench::harness::bench;
use itq3s::kvpaged::{BlockPool, KvQuant, PagedKvPool};
use itq3s::model::native::Engine;
use itq3s::model::{DenseModel, KvCache, ModelConfig, NativeEngine};
use itq3s::util::json::Json;
use itq3s::util::XorShift;
use std::collections::BTreeMap;

fn main() {
    let cfg = ModelConfig::tiny(); // max_seq 256: room for a deep context
    let eng = NativeEngine::dense(DenseModel::random(&cfg, 42, Some(5.0)));
    let mut rng = XorShift::new(7);
    let prompt: Vec<u32> = (0..128).map(|_| rng.next_below(256) as u32).collect();
    let decode_tokens: Vec<u32> = (0..16).map(|_| rng.next_below(256) as u32).collect();
    let mut report: BTreeMap<String, Json> = BTreeMap::new();

    // --- decode-step latency at context ~128 -------------------------
    // Each measured iteration replays 16 decode steps on a prefilled
    // store (fresh store per iteration so depth stays comparable).
    let steps = decode_tokens.len() as f64;
    let r_dense = bench("dense", 1, 5, || {
        let mut c = KvCache::new(&cfg);
        eng.prefill(&mut c, &prompt);
        for &t in &decode_tokens {
            let _ = eng.decode_step(&mut c, t);
        }
    });
    println!(
        "decode ctx=128 dense-f32        {:>8.1} us/step",
        r_dense.mean_s / steps * 1e6
    );

    let mut variants: BTreeMap<String, Json> = BTreeMap::new();
    for &bt in &[4usize, 16, 64] {
        for &quant in &[KvQuant::F32, KvQuant::Q8] {
            let label = format!("paged_{}_bt{}", quant.as_str(), bt);
            let r = bench(&label, 1, 5, || {
                let mut pool = PagedKvPool::new(&cfg, bt, quant, 64 << 20);
                let id = pool.create_seq();
                eng.prefill(&mut pool.seq_view(id), &prompt);
                for &t in &decode_tokens {
                    let _ = eng.decode_step(&mut pool.seq_view(id), t);
                }
                pool.release_seq(id);
            });
            println!(
                "decode ctx=128 paged-{:<3} bt={bt:<2} {:>8.1} us/step  ({:.2}x vs dense)",
                quant.as_str(),
                r.mean_s / steps * 1e6,
                r.mean_s / r_dense.mean_s
            );
            variants.insert(
                label,
                Json::obj(vec![
                    ("us_per_step", Json::num(r.mean_s / steps * 1e6)),
                    ("slowdown_vs_dense", Json::num(r.mean_s / r_dense.mean_s)),
                ]),
            );
        }
    }
    report.insert(
        "decode_step".to_string(),
        Json::obj(vec![
            ("context", Json::num(128.0)),
            ("decode_steps", Json::num(steps)),
            ("dense_us_per_step", Json::num(r_dense.mean_s / steps * 1e6)),
            ("variants", Json::Obj(variants)),
        ]),
    );

    // --- capacity: tokens per 64 MiB budget --------------------------
    let budget = 64usize << 20;
    let mut cap: BTreeMap<String, Json> = BTreeMap::new();
    for &quant in &[KvQuant::F32, KvQuant::Q8] {
        let pool = BlockPool::new(&cfg, 16, quant, budget);
        let tokens = pool.capacity_blocks() * pool.block_tokens();
        println!(
            "capacity 64MiB {}: {} blocks = {} tokens",
            quant.as_str(),
            pool.capacity_blocks(),
            tokens
        );
        cap.insert(
            quant.as_str().to_string(),
            Json::obj(vec![
                ("blocks", Json::num(pool.capacity_blocks() as f64)),
                ("tokens", Json::num(tokens as f64)),
            ]),
        );
    }
    report.insert(
        "capacity_64mib".to_string(),
        Json::obj(vec![("block_tokens", Json::num(16.0)), ("by_quant", Json::Obj(cap))]),
    );

    let out = Json::Obj(report).to_string();
    match std::fs::write("BENCH_kv.json", &out) {
        Ok(()) => println!("wrote BENCH_kv.json"),
        Err(e) => eprintln!("could not write BENCH_kv.json: {e}"),
    }
}
