//! Regenerates paper Table 2 (decode/prefill throughput).
fn main() {
    itq3s::bench::tables::table2("artifacts").unwrap_or_else(|e| {
        eprintln!("table2: {e:#} (run `make artifacts` first)");
    });
}
