//! Regenerates paper Table 3 (FWHT block-size ablation).
fn main() {
    itq3s::bench::tables::table3("artifacts").unwrap_or_else(|e| {
        eprintln!("table3: {e:#} (run `make artifacts` first)");
    });
}
