//! Batched-GEMM B-sweep: how much per-token latency the fused
//! multi-sequence decode path buys as the batch grows. Two levels:
//!
//! 1. **kernel** — `QuantizedLinear::gemm_q8` vs B independent
//!    `matvec_q8` calls on a serving-ish layer, per hot format;
//! 2. **engine** — `NativeEngine::decode_batch` vs B sequential
//!    `decode_step`s on the tiny model at a real context depth
//!    (tokens/s at B ∈ {1, 4, 8, 16} — the acceptance number).
//!
//! Writes `BENCH_gemm.json`; the expected-shape table lives in
//! EXPERIMENTS.md §Batched.

use itq3s::bench::harness::bench;
use itq3s::model::native::Engine;
use itq3s::model::{
    DenseModel, KvCache, KvStore, ModelConfig, NativeEngine, QuantizedModel, StoreBatch,
};
use itq3s::quant::format_by_name;
use itq3s::quant::matmul::{MatvecScratch, QuantizedLinear};
use itq3s::quant::simd;
use itq3s::tensor::Tensor;
use itq3s::util::json::Json;
use itq3s::util::XorShift;
use std::collections::BTreeMap;

const BATCHES: [usize; 4] = [1, 4, 8, 16];

fn main() {
    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    println!("simd tier: {}", simd::active_tier().name());
    report.insert("simd_tier".to_string(), Json::str(simd::active_tier().name()));

    // --- 1. kernel level: fused GEMM vs per-row matvec loop ----------
    let (rows, cols) = (1024usize, 2048usize);
    let mut rng = XorShift::new(5);
    let mut w = Tensor::zeros(vec![rows, cols]);
    for v in w.data_mut() {
        *v = (rng.next_student_t(5.0) as f32) * 0.02;
    }
    let mut kernel: BTreeMap<String, Json> = BTreeMap::new();
    for fmt_name in ["itq3_s", "q8_0"] {
        let lin = QuantizedLinear::new(format_by_name(fmt_name).unwrap(), &w);
        let mut per_fmt: BTreeMap<String, Json> = BTreeMap::new();
        let mut base_tps = 0.0f64;
        for &b in &BATCHES {
            let x: Vec<f32> = (0..b * cols).map(|_| rng.next_f32() - 0.5).collect();
            let mut y = vec![0.0f32; b * rows];
            let mut scratch = MatvecScratch::new();
            let r_loop = bench("matvec-loop", 1, 5, || {
                for t in 0..b {
                    lin.matvec_q8(
                        &x[t * cols..(t + 1) * cols],
                        &mut y[t * rows..(t + 1) * rows],
                        &mut scratch,
                        1,
                    );
                }
            });
            let r_gemm = bench("gemm", 1, 5, || {
                lin.gemm_q8(&x, b, &mut y, &mut scratch, 1);
            });
            // Same GEMM with dispatch pinned to the scalar oracle — the
            // per-batch SIMD speedup on bit-identical outputs.
            simd::set_enabled(false);
            let r_scalar = bench("gemm scalar", 1, 5, || {
                lin.gemm_q8(&x, b, &mut y, &mut scratch, 1);
            });
            simd::set_enabled(true);
            let tps = b as f64 / r_gemm.mean_s;
            if b == 1 {
                base_tps = tps;
            }
            let speedup = r_loop.mean_s / r_gemm.mean_s;
            let simd_speedup = r_scalar.mean_s / r_gemm.mean_s;
            println!(
                "kernel {fmt_name:<7} {rows}x{cols} B={b:<2} {:>9.1} matvec-eq/s  \
                 ({speedup:.2}x vs per-row matvec loop, {simd_speedup:.2}x vs scalar)",
                tps
            );
            per_fmt.insert(
                format!("b{b}"),
                Json::obj(vec![
                    ("matvecs_per_s", Json::num(tps)),
                    ("speedup_vs_matvec_loop", Json::num(speedup)),
                    ("scalar_matvecs_per_s", Json::num(b as f64 / r_scalar.mean_s)),
                    ("simd_speedup_vs_scalar", Json::num(simd_speedup)),
                    ("scaling_vs_b1", Json::num(if base_tps > 0.0 { tps / base_tps } else { 0.0 })),
                ]),
            );
        }
        kernel.insert(fmt_name.to_string(), Json::Obj(per_fmt));
    }
    report.insert(
        "gemm_kernel".to_string(),
        Json::obj(vec![
            ("rows", Json::num(rows as f64)),
            ("cols", Json::num(cols as f64)),
            ("threads", Json::num(1.0)),
            ("by_format", Json::Obj(kernel)),
        ]),
    );

    // --- 2. engine level: fused decode rounds, tokens/s --------------
    let cfg = ModelConfig::tiny();
    let dense = DenseModel::random(&cfg, 42, Some(5.0));
    let eng =
        NativeEngine::quantized(QuantizedModel::quantize(&dense, format_by_name("itq3_s").unwrap()));
    let context = 64usize;
    let steps = 6usize;
    let mut engine_rep: BTreeMap<String, Json> = BTreeMap::new();
    let mut b1_tps = 0.0f64;
    for &b in &BATCHES {
        let prompts: Vec<Vec<u32>> = (0..b)
            .map(|s| (0..context as u32).map(|i| (i * 31 + s as u32 * 13) % 256).collect())
            .collect();
        let mut caches: Vec<KvCache> = prompts
            .iter()
            .map(|p| {
                let mut c = KvCache::new(&cfg);
                eng.prefill(&mut c, p);
                c
            })
            .collect();
        let toks: Vec<u32> = (0..b as u32).map(|s| (s * 5 + 1) % 256).collect();
        // Each measured iteration: `steps` fused rounds (context creeps
        // by a few tokens across iterations; depth stays comparable).
        let r = bench("decode_batch", 1, 5, || {
            for _ in 0..steps {
                let stores: Vec<&mut dyn KvStore> =
                    caches.iter_mut().map(|c| c as &mut dyn KvStore).collect();
                let mut kv = StoreBatch { stores };
                let _ = eng.decode_batch(&mut kv, &toks);
            }
        });
        let tps = (b * steps) as f64 / r.mean_s;
        if b == 1 {
            b1_tps = tps;
        }
        println!(
            "engine itq3_s ctx~{context} B={b:<2} {:>9.1} tokens/s  ({:.2}x vs B=1)",
            tps,
            if b1_tps > 0.0 { tps / b1_tps } else { 0.0 }
        );
        engine_rep.insert(
            format!("b{b}"),
            Json::obj(vec![
                ("tokens_per_s", Json::num(tps)),
                ("scaling_vs_b1", Json::num(if b1_tps > 0.0 { tps / b1_tps } else { 0.0 })),
            ]),
        );
    }
    report.insert(
        "engine_decode".to_string(),
        Json::obj(vec![
            ("model", Json::str("tiny/itq3_s")),
            ("context", Json::num(context as f64)),
            ("steps_per_iter", Json::num(steps as f64)),
            ("by_batch", Json::Obj(engine_rep)),
        ]),
    );

    let out = Json::Obj(report).to_string();
    match std::fs::write("BENCH_gemm.json", &out) {
        Ok(()) => println!("wrote BENCH_gemm.json"),
        Err(e) => eprintln!("could not write BENCH_gemm.json: {e}"),
    }
}
