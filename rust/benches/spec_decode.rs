//! Speculative-decoding bench: accepted-tokens/s over draft length
//! {0, 2, 4, 8} x acceptance regime (repetitive vs adversarial
//! prompts), single stream on the itq3_s W3A8 engine over a paged f32
//! pool — the configuration the coordinator actually serves. Draft
//! length 0 is the vanilla one-token-per-pass baseline. A second
//! sweep measures *sampled* speculation (accept rate and tokens/s vs
//! temperature at fixed draft length) now that the rejection-sampling
//! verify loop makes sampled requests speculate too. Writes
//! `BENCH_spec.json` (schema documented in EXPERIMENTS.md §Benchmark
//! artifacts) so EXPERIMENTS.md §Speculative / §Sampled-speculation
//! have a machine-readable trajectory across PRs.

use itq3s::bench::harness::bench;
use itq3s::coordinator::sampler::Sampler;
use itq3s::kvpaged::{KvQuant, PagedKvPool};
use itq3s::model::{DenseModel, ModelConfig, NativeEngine, QuantizedModel};
use itq3s::spec::{run_greedy, run_sampled, NgramDrafter, SpecRun};
use itq3s::util::json::Json;
use itq3s::util::XorShift;
use std::collections::BTreeMap;

/// One measured generation: `n` greedy tokens at draft length `k`
/// (0 = vanilla — `run_greedy` then never enters a verify pass) on a
/// fresh paged pool. Shares `spec::run_greedy` with the differential
/// tests, so the measured protocol is exactly the tested one.
fn run(eng: &NativeEngine, prompt: &[u32], cfg: &ModelConfig, n: usize, k: usize) -> SpecRun {
    let mut pool = PagedKvPool::new(cfg, 16, KvQuant::F32, 64 << 20);
    let id = pool.create_seq();
    let r = run_greedy(eng, &mut pool.seq_view(id), prompt, n, &mut NgramDrafter::default(), k);
    pool.release_seq(id);
    r
}

/// Sampled variant: same protocol through `spec::run_sampled` with a
/// fresh same-seed sampler per run (determinism makes the un-timed
/// accounting run identical to the timed ones).
fn run_t(
    eng: &NativeEngine,
    prompt: &[u32],
    cfg: &ModelConfig,
    n: usize,
    k: usize,
    temperature: f32,
) -> SpecRun {
    let mut pool = PagedKvPool::new(cfg, 16, KvQuant::F32, 64 << 20);
    let id = pool.create_seq();
    let mut sampler = Sampler::new(temperature, 1234).with_top_k(Some(40));
    let r = run_sampled(
        eng,
        &mut pool.seq_view(id),
        prompt,
        n,
        &mut NgramDrafter::default(),
        k,
        &mut sampler,
    );
    pool.release_seq(id);
    r
}

fn main() {
    let cfg = ModelConfig::tiny(); // max_seq 256: room for prompt + drafts
    let dense = DenseModel::random(&cfg, 42, Some(5.0));
    let fmt = itq3s::quant::format_by_name("itq3_s").unwrap();
    let eng = NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt));

    // Repetitive prompt: period-4 token cycle the ngram drafter can
    // exploit. Adversarial: uniform random bytes — drafts rarely land.
    let repetitive: Vec<u32> = (0..64u32).map(|i| 40 + (i % 4)).collect();
    let mut rng = XorShift::new(7);
    let adversarial: Vec<u32> = (0..64).map(|_| rng.next_below(256) as u32).collect();
    let gen_tokens = 48usize;

    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    for (regime, prompt) in [("repetitive", &repetitive), ("adversarial", &adversarial)] {
        let mut by_k: BTreeMap<String, Json> = BTreeMap::new();
        let mut base_tps = 0.0f64;
        for &k in &[0usize, 2, 4, 8] {
            // Acceptance accounting from one un-timed run (identical
            // to the timed ones — everything is deterministic).
            let SpecRun { drafted, accepted, .. } = run(&eng, prompt, &cfg, gen_tokens, k);
            let label = format!("{regime}_k{k}");
            let r = bench(&label, 1, 5, || {
                run(&eng, prompt, &cfg, gen_tokens, k);
            });
            let tps = gen_tokens as f64 / r.mean_s;
            if k == 0 {
                base_tps = tps;
            }
            let accept_rate =
                if drafted > 0 { accepted as f64 / drafted as f64 } else { 0.0 };
            println!(
                "{regime:<11} k={k}: {tps:>9.1} tok/s ({:.2}x vs k=0), accept {:.0}% ({accepted}/{drafted})",
                tps / base_tps,
                accept_rate * 100.0
            );
            by_k.insert(
                format!("k{k}"),
                Json::obj(vec![
                    ("tokens_per_s", Json::num(tps)),
                    ("speedup_vs_vanilla", Json::num(tps / base_tps)),
                    ("drafted", Json::num(drafted as f64)),
                    ("accepted", Json::num(accepted as f64)),
                    ("accept_rate", Json::num(accept_rate)),
                ]),
            );
        }
        report.insert(
            regime.to_string(),
            Json::obj(vec![
                ("gen_tokens", Json::num(gen_tokens as f64)),
                ("prompt_tokens", Json::num(prompt.len() as f64)),
                ("by_draft_len", Json::Obj(by_k)),
            ]),
        );
    }

    // §Sampled-speculation: accept rate and accepted-tokens/s vs
    // temperature at the serving default draft length (k=4, top-k 40),
    // repetitive prompt. temperature 0 is the greedy reference point
    // of the same loop; rising temperature flattens the target
    // distribution, so point-mass drafts get accepted less often and
    // the speedup decays toward the verify-pass overhead — this sweep
    // prices that decay.
    let spec_k = 4usize;
    let mut by_t: BTreeMap<String, Json> = BTreeMap::new();
    let mut base_tps = 0.0f64;
    for &temp in &[0.0f32, 0.3, 0.6, 0.9, 1.2] {
        let SpecRun { drafted, accepted, resampled, .. } =
            run_t(&eng, &repetitive, &cfg, gen_tokens, spec_k, temp);
        // Vanilla baseline at the same temperature (k=0): the honest
        // denominator, since sampling itself costs a little.
        let rb = bench(&format!("sampled_t{temp}_k0"), 1, 5, || {
            run_t(&eng, &repetitive, &cfg, gen_tokens, 0, temp);
        });
        let r = bench(&format!("sampled_t{temp}_k{spec_k}"), 1, 5, || {
            run_t(&eng, &repetitive, &cfg, gen_tokens, spec_k, temp);
        });
        let tps = gen_tokens as f64 / r.mean_s;
        let vanilla_tps = gen_tokens as f64 / rb.mean_s;
        if temp == 0.0 {
            base_tps = tps;
        }
        let accept_rate = if drafted > 0 { accepted as f64 / drafted as f64 } else { 0.0 };
        println!(
            "sampled t={temp:<4} k={spec_k}: {tps:>9.1} tok/s ({:.2}x vs own k=0, {:.2}x vs t=0), accept {:.0}% ({accepted}/{drafted}), resampled {resampled}",
            tps / vanilla_tps,
            tps / base_tps,
            accept_rate * 100.0
        );
        by_t.insert(
            format!("t{temp}"),
            Json::obj(vec![
                ("tokens_per_s", Json::num(tps)),
                ("vanilla_tokens_per_s", Json::num(vanilla_tps)),
                ("speedup_vs_vanilla", Json::num(tps / vanilla_tps)),
                ("drafted", Json::num(drafted as f64)),
                ("accepted", Json::num(accepted as f64)),
                ("accept_rate", Json::num(accept_rate)),
                ("resampled_rounds", Json::num(resampled as f64)),
            ]),
        );
    }
    report.insert(
        "sampled".to_string(),
        Json::obj(vec![
            ("gen_tokens", Json::num(gen_tokens as f64)),
            ("prompt_tokens", Json::num(repetitive.len() as f64)),
            ("draft_len", Json::num(spec_k as f64)),
            ("top_k", Json::num(40.0)),
            ("seed", Json::num(1234.0)),
            ("by_temperature", Json::Obj(by_t)),
        ]),
    );

    let out = Json::Obj(report).to_string();
    match std::fs::write("BENCH_spec.json", &out) {
        Ok(()) => println!("wrote BENCH_spec.json"),
        Err(e) => eprintln!("could not write BENCH_spec.json: {e}"),
    }
}
