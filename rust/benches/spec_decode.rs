//! Speculative-decoding bench: accepted-tokens/s over draft length
//! {0, 2, 4, 8} x acceptance regime (repetitive vs adversarial
//! prompts), single stream on the itq3_s W3A8 engine over a paged f32
//! pool — the configuration the coordinator actually serves. Draft
//! length 0 is the vanilla one-token-per-pass baseline. Writes
//! `BENCH_spec.json` so EXPERIMENTS.md §Speculative has a
//! machine-readable trajectory across PRs.

use itq3s::bench::harness::bench;
use itq3s::kvpaged::{KvQuant, PagedKvPool};
use itq3s::model::{DenseModel, ModelConfig, NativeEngine, QuantizedModel};
use itq3s::spec::{run_greedy, NgramDrafter, SpecRun};
use itq3s::util::json::Json;
use itq3s::util::XorShift;
use std::collections::BTreeMap;

/// One measured generation: `n` greedy tokens at draft length `k`
/// (0 = vanilla — `run_greedy` then never enters a verify pass) on a
/// fresh paged pool. Shares `spec::run_greedy` with the differential
/// tests, so the measured protocol is exactly the tested one.
fn run(eng: &NativeEngine, prompt: &[u32], cfg: &ModelConfig, n: usize, k: usize) -> SpecRun {
    let mut pool = PagedKvPool::new(cfg, 16, KvQuant::F32, 64 << 20);
    let id = pool.create_seq();
    let r = run_greedy(eng, &mut pool.seq_view(id), prompt, n, &mut NgramDrafter::default(), k);
    pool.release_seq(id);
    r
}

fn main() {
    let cfg = ModelConfig::tiny(); // max_seq 256: room for prompt + drafts
    let dense = DenseModel::random(&cfg, 42, Some(5.0));
    let fmt = itq3s::quant::format_by_name("itq3_s").unwrap();
    let eng = NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt));

    // Repetitive prompt: period-4 token cycle the ngram drafter can
    // exploit. Adversarial: uniform random bytes — drafts rarely land.
    let repetitive: Vec<u32> = (0..64u32).map(|i| 40 + (i % 4)).collect();
    let mut rng = XorShift::new(7);
    let adversarial: Vec<u32> = (0..64).map(|_| rng.next_below(256) as u32).collect();
    let gen_tokens = 48usize;

    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    for (regime, prompt) in [("repetitive", &repetitive), ("adversarial", &adversarial)] {
        let mut by_k: BTreeMap<String, Json> = BTreeMap::new();
        let mut base_tps = 0.0f64;
        for &k in &[0usize, 2, 4, 8] {
            // Acceptance accounting from one un-timed run (identical
            // to the timed ones — everything is deterministic).
            let SpecRun { drafted, accepted, .. } = run(&eng, prompt, &cfg, gen_tokens, k);
            let label = format!("{regime}_k{k}");
            let r = bench(&label, 1, 5, || {
                run(&eng, prompt, &cfg, gen_tokens, k);
            });
            let tps = gen_tokens as f64 / r.mean_s;
            if k == 0 {
                base_tps = tps;
            }
            let accept_rate =
                if drafted > 0 { accepted as f64 / drafted as f64 } else { 0.0 };
            println!(
                "{regime:<11} k={k}: {tps:>9.1} tok/s ({:.2}x vs k=0), accept {:.0}% ({accepted}/{drafted})",
                tps / base_tps,
                accept_rate * 100.0
            );
            by_k.insert(
                format!("k{k}"),
                Json::obj(vec![
                    ("tokens_per_s", Json::num(tps)),
                    ("speedup_vs_vanilla", Json::num(tps / base_tps)),
                    ("drafted", Json::num(drafted as f64)),
                    ("accepted", Json::num(accepted as f64)),
                    ("accept_rate", Json::num(accept_rate)),
                ]),
            );
        }
        report.insert(
            regime.to_string(),
            Json::obj(vec![
                ("gen_tokens", Json::num(gen_tokens as f64)),
                ("prompt_tokens", Json::num(prompt.len() as f64)),
                ("by_draft_len", Json::Obj(by_k)),
            ]),
        );
    }

    let out = Json::Obj(report).to_string();
    match std::fs::write("BENCH_spec.json", &out) {
        Ok(()) => println!("wrote BENCH_spec.json"),
        Err(e) => eprintln!("could not write BENCH_spec.json: {e}"),
    }
}
