//! Numerics-audit overhead bench: decode throughput through the full
//! coordinator on the itq3_s W3A8 engine as the shadow-probe sample
//! rate sweeps 0 -> 1. Each sampled round replays one active sequence
//! through the f32 reference path, so the cost scales with the rate:
//! R=0 must price at zero (the hook is a single branch), R=1 roughly
//! doubles per-round model work for one sequence. Audit sampling must
//! never change the generated tokens (enforced by tests/replicas.rs);
//! this bench prices what the observability *costs*. Writes
//! `BENCH_audit.json` (schema in EXPERIMENTS.md §Benchmark artifacts).

use itq3s::bench::harness::bench;
use itq3s::coordinator::{Coordinator, CoordinatorConfig, Event, GenRequest};
use itq3s::model::{DenseModel, ModelConfig, NativeEngine, QuantizedModel};
use itq3s::util::json::Json;
use std::collections::BTreeMap;

/// Run one generation to completion, returning generated-token count.
fn run_one(c: &Coordinator, prompt: &str, n: usize) -> usize {
    let rx = c.generate(GenRequest {
        prompt: prompt.to_string(),
        max_new_tokens: n,
        ..Default::default()
    });
    for ev in rx.iter() {
        match ev {
            Event::Done { gen_tokens, .. } => return gen_tokens,
            Event::Error(e) => panic!("bench request failed: {e:?}"),
            _ => {}
        }
    }
    panic!("stream ended without a terminal event");
}

fn main() {
    let cfg = ModelConfig::tiny();
    let dense = DenseModel::random(&cfg, 42, Some(5.0));

    let prompt = "the quick brown fox jumps over the lazy dog. ".repeat(3);
    let gen_tokens = 48usize;

    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    report.insert("gen_tokens".into(), Json::num(gen_tokens as f64));
    report.insert("prompt_bytes".into(), Json::num(prompt.len() as f64));

    let rates = [0.0f64, 0.01, 0.1, 1.0];
    let mut baseline_tps = 0.0f64;
    let mut rows = Vec::new();
    for (i, &rate) in rates.iter().enumerate() {
        let fmt = itq3s::quant::format_by_name("itq3_s").unwrap();
        let eng = NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt));
        let coord = Coordinator::new(
            Box::new(eng),
            CoordinatorConfig {
                max_batch: 4,
                kv_budget_bytes: 64 << 20,
                audit_sample_rate: rate,
                ..Default::default()
            },
        );
        let label = format!("audit_rate_{rate}");
        let got = run_one(&coord, &prompt, gen_tokens);
        assert_eq!(got, gen_tokens, "{label}: short generation");
        let r = bench(&label, 1, 5, || {
            run_one(&coord, &prompt, gen_tokens);
        });
        let tps = gen_tokens as f64 / r.mean_s;
        if i == 0 {
            baseline_tps = tps;
        }
        let overhead_pct = (baseline_tps / tps - 1.0) * 100.0;
        println!(
            "rate {rate:<5}: {tps:>8.1} tok/s ({overhead_pct:+.1}% vs unaudited)"
        );
        rows.push(Json::obj(vec![
            ("audit_sample_rate", Json::num(rate)),
            ("tokens_per_s", Json::num(tps)),
            ("overhead_pct", Json::num(overhead_pct)),
        ]));
    }
    report.insert("rates".into(), Json::Arr(rows));

    let out = Json::Obj(report).to_string();
    match std::fs::write("BENCH_audit.json", &out) {
        Ok(()) => println!("wrote BENCH_audit.json"),
        Err(e) => eprintln!("could not write BENCH_audit.json: {e}"),
    }
}
