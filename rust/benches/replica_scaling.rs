//! Replica-scaling bench: end-to-end throughput of one mixed serving
//! workload (shared-prefix groups that exercise placement affinity
//! plus fresh prompts) through the coordinator with N ∈ {1, 2, 4}
//! data-parallel replicas of the same itq3_s W3A8 engine. All replicas
//! share this host's physical cores, so the numbers price scheduler
//! overhead and placement quality under contention rather than ideal
//! N× scaling — the interesting signal is that N=1 matches the
//! pre-replica coordinator and N>1 does not collapse. Writes
//! `BENCH_replica.json` (schema in EXPERIMENTS.md §Replica scaling).

use itq3s::bench::harness::bench;
use itq3s::coordinator::{Coordinator, CoordinatorConfig, Event, GenRequest};
use itq3s::model::native::Engine;
use itq3s::model::{DenseModel, ModelConfig, NativeEngine, QuantizedModel};
use itq3s::util::json::Json;
use std::collections::BTreeMap;

/// Submit the whole mixed workload, drain every stream, and return the
/// total generated-token count.
fn drain_workload(c: &Coordinator) -> usize {
    let mut rxs = Vec::new();
    for group in 0..4 {
        // Three requests per group share a long prompt prefix, so
        // after the first completes the others should follow it to the
        // replica that cached the prefix.
        let prefix = format!("shared context for group {group}: the quick brown fox. ");
        for j in 0..3 {
            rxs.push(c.generate(GenRequest {
                prompt: format!("{prefix}request {j}"),
                max_new_tokens: 16,
                ..Default::default()
            }));
        }
    }
    let mut total = 0;
    for rx in rxs {
        for ev in rx.iter() {
            match ev {
                Event::Done { gen_tokens, .. } => {
                    total += gen_tokens;
                    break;
                }
                Event::Error(e) => panic!("bench request failed: {e:?}"),
                _ => {}
            }
        }
    }
    total
}

fn main() {
    let cfg = ModelConfig::tiny();
    let dense = DenseModel::random(&cfg, 42, Some(5.0));

    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    report.insert("requests".into(), Json::num(12.0));
    report.insert("gen_tokens_per_request".into(), Json::num(16.0));

    let mut base_tps = 0.0f64;
    for n in [1usize, 2, 4] {
        let fmt = itq3s::quant::format_by_name("itq3_s").unwrap();
        let engines: Vec<Box<dyn Engine>> = (0..n)
            .map(|_| {
                Box::new(NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt.clone())))
                    as Box<dyn Engine>
            })
            .collect();
        let coord = Coordinator::new_replicated(
            engines,
            CoordinatorConfig {
                max_batch: 4,
                kv_budget_bytes: (64 << 20) * n,
                ..Default::default()
            },
        );
        let total = drain_workload(&coord); // warm pass primes prefix caches
        assert_eq!(total, 12 * 16, "replicas={n}: short generation");
        let r = bench(&format!("replicas_{n}"), 1, 5, || {
            drain_workload(&coord);
        });
        let tps = (12 * 16) as f64 / r.mean_s;
        if n == 1 {
            base_tps = tps;
        }
        let speedup = tps / base_tps;
        println!("replicas={n}: {tps:>8.1} tok/s ({speedup:.2}x vs N=1)");
        report.insert(
            format!("replicas_{n}"),
            Json::obj(vec![
                ("tokens_per_s", Json::num(tps)),
                ("speedup_vs_1", Json::num(speedup)),
            ]),
        );
        coord.shutdown();
    }

    let out = Json::Obj(report).to_string();
    match std::fs::write("BENCH_replica.json", &out) {
        Ok(()) => println!("wrote BENCH_replica.json"),
        Err(e) => eprintln!("could not write BENCH_replica.json: {e}"),
    }
}
