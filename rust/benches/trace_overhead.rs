//! Observability-overhead bench: decode throughput through the full
//! coordinator with per-request tracing off vs on (`"trace": true`),
//! plus the same pair again under speculation, on the itq3_s W3A8
//! engine. Tracing must never change the generated tokens; this bench
//! prices what it *does* cost (a handful of `Instant` reads and small
//! event pushes per round — expected noise-level). When built with
//! `--features profiling` the phase-profiler scopes are live too, so
//! the run also prices the instrumented engine. Writes
//! `BENCH_obs.json` (schema in EXPERIMENTS.md §Benchmark artifacts).

use itq3s::bench::harness::bench;
use itq3s::coordinator::{Coordinator, CoordinatorConfig, Event, GenRequest};
use itq3s::model::{DenseModel, ModelConfig, NativeEngine, QuantizedModel};
use itq3s::util::json::Json;
use itq3s::util::profile;
use std::collections::BTreeMap;

/// Run one generation to completion, returning generated-token count.
fn run_one(c: &Coordinator, prompt: &str, n: usize, trace: bool) -> usize {
    let rx = c.generate(GenRequest {
        prompt: prompt.to_string(),
        max_new_tokens: n,
        trace,
        ..Default::default()
    });
    for ev in rx.iter() {
        match ev {
            Event::Done { gen_tokens, .. } => return gen_tokens,
            Event::Error(e) => panic!("bench request failed: {e:?}"),
            _ => {}
        }
    }
    panic!("stream ended without a terminal event");
}

fn main() {
    let cfg = ModelConfig::tiny();
    let dense = DenseModel::random(&cfg, 42, Some(5.0));

    let prompt = "the quick brown fox jumps over the lazy dog. ".repeat(3);
    let gen_tokens = 48usize;

    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    report.insert("gen_tokens".into(), Json::num(gen_tokens as f64));
    report.insert("prompt_bytes".into(), Json::num(prompt.len() as f64));
    report.insert("profiling_enabled".into(), Json::Bool(profile::ENABLED));

    for (mode, draft_len) in [("vanilla", 0usize), ("speculative", 4)] {
        let fmt = itq3s::quant::format_by_name("itq3_s").unwrap();
        let eng = NativeEngine::quantized(QuantizedModel::quantize(&dense, fmt));
        let coord = Coordinator::new(
            Box::new(eng),
            CoordinatorConfig {
                max_batch: 4,
                kv_budget_bytes: 64 << 20,
                spec_draft_len: draft_len,
                ..Default::default()
            },
        );
        let mut tps = [0.0f64; 2];
        for (i, traced) in [false, true].into_iter().enumerate() {
            let label = format!("{mode}_{}", if traced { "traced" } else { "untraced" });
            let got = run_one(&coord, &prompt, gen_tokens, traced);
            assert_eq!(got, gen_tokens, "{label}: short generation");
            let r = bench(&label, 1, 5, || {
                run_one(&coord, &prompt, gen_tokens, traced);
            });
            tps[i] = gen_tokens as f64 / r.mean_s;
        }
        let overhead_pct = (tps[0] / tps[1] - 1.0) * 100.0;
        println!(
            "{mode:<12}: untraced {:>8.1} tok/s, traced {:>8.1} tok/s ({overhead_pct:+.1}% overhead)",
            tps[0], tps[1]
        );
        report.insert(
            mode.to_string(),
            Json::obj(vec![
                ("untraced_tokens_per_s", Json::num(tps[0])),
                ("traced_tokens_per_s", Json::num(tps[1])),
                ("trace_overhead_pct", Json::num(overhead_pct)),
            ]),
        );
    }

    let out = Json::Obj(report).to_string();
    match std::fs::write("BENCH_obs.json", &out) {
        Ok(()) => println!("wrote BENCH_obs.json"),
        Err(e) => eprintln!("could not write BENCH_obs.json: {e}"),
    }
}
