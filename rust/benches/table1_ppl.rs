//! Regenerates paper Table 1 (perplexity vs bit-width).
//! `ITQ3S_PPL_BYTES` controls text volume per cell (default 8192).
fn main() {
    itq3s::bench::tables::table1("artifacts").unwrap_or_else(|e| {
        eprintln!("table1: {e:#} (run `make artifacts` first)");
    });
}
