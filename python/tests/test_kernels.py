"""Kernel-vs-reference correctness: the CORE L1 signal.

Pallas kernels (interpret mode) must match the pure-numpy oracles in
``compile.kernels.ref`` — including a hypothesis sweep over shapes and
weight distributions (heavy tails, planted outliers).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fwht import fwht_blocked
from compile.kernels.itq3s_matmul import dequant_matmul, dequantize


def rng(seed=0):
    return np.random.default_rng(seed)


class TestFwhtRefs:
    def test_butterfly_matches_dense_matrix(self):
        for n in [2, 8, 32, 256, 512]:
            x = rng(n).standard_normal((3, n)).astype(np.float32)
            np.testing.assert_allclose(
                ref.fwht_butterfly(x), ref.fwht_ref(x), rtol=0, atol=1e-4
            )

    def test_involution(self):
        x = rng(1).standard_normal((4, 256)).astype(np.float32)
        y = ref.fwht_butterfly(ref.fwht_butterfly(x))
        np.testing.assert_allclose(y, x, atol=1e-4)

    def test_isometry(self):
        x = rng(2).standard_normal((4, 256)).astype(np.float32)
        y = ref.fwht_butterfly(x)
        np.testing.assert_allclose(
            np.linalg.norm(y, axis=-1), np.linalg.norm(x, axis=-1), rtol=1e-5
        )


class TestFwhtKernel:
    def test_matches_ref_256(self):
        x = rng(3).standard_normal((64, 512)).astype(np.float32)
        got = np.asarray(fwht_blocked(x, 256))
        want = ref.fwht_butterfly(x.reshape(64, 2, 256)).reshape(64, 512)
        np.testing.assert_allclose(got, want, atol=1e-4)

    @pytest.mark.parametrize("block", [32, 64, 128, 256, 512])
    def test_ablation_block_sizes(self, block):
        x = rng(block).standard_normal((8, 512)).astype(np.float32)
        got = np.asarray(fwht_blocked(x, block))
        nb = 512 // block
        want = ref.fwht_butterfly(x.reshape(8, nb, block)).reshape(8, 512)
        np.testing.assert_allclose(got, want, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        rows=st.sampled_from([8, 64]),
        nb=st.integers(1, 3),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_shapes(self, rows, nb, seed):
        x = rng(seed).standard_normal((rows, nb * 256)).astype(np.float32)
        got = np.asarray(fwht_blocked(x, 256))
        want = ref.fwht_butterfly(x.reshape(rows, nb, 256)).reshape(rows, nb * 256)
        np.testing.assert_allclose(got, want, atol=1e-4)


class TestPacking:
    def test_pack_unpack_roundtrip(self):
        w = rng(4).standard_normal((8, 512)).astype(np.float32) * 0.02
        q = ref.quantize_matrix(w)
        rot = ref.unpack_ref(q, 8, 512)
        # Every unpacked value must be on the grid {0, +-d, +-3d} + z.
        nb = 2
        for r in range(8):
            for b in range(nb):
                d, z = q["d"][r, b], q["z"][r, b]
                vals = rot[r, b * 256 : (b + 1) * 256] - z
                grid = np.array([-3 * d, -d, 0, d, 3 * d])
                dist = np.abs(vals[:, None] - grid[None, :]).min(axis=1)
                assert dist.max() < 1e-6

    def test_reconstruction_error_reasonable(self):
        w = rng(5).standard_normal((16, 256)).astype(np.float32) * 0.05
        q = ref.quantize_matrix(w)
        w_hat = ref.dequantize_matrix_ref(q, 16, 256)
        rel = np.linalg.norm(w_hat - w) / np.linalg.norm(w)
        assert rel < 0.62, rel


class TestFusedKernel:
    def test_dequantize_matches_ref(self):
        w = rng(6).standard_normal((64, 256)).astype(np.float32) * 0.03
        q = ref.quantize_matrix(w)
        got = np.asarray(dequantize(q["codes"], q["sel"], q["d"], q["z"], rows=64, cols=256))
        want = ref.dequantize_matrix_ref(q, 64, 256)
        np.testing.assert_allclose(got, want, atol=1e-4)

    def test_fused_matmul_matches_ref(self):
        w = rng(7).standard_normal((64, 512)).astype(np.float32) * 0.03
        x = rng(8).standard_normal((512, 5)).astype(np.float32)
        q = ref.quantize_matrix(w)
        got = np.asarray(
            dequant_matmul(q["codes"], q["sel"], q["d"], q["z"], x, rows=64, cols=512)
        )
        want = ref.dequant_matmul_ref(q, 64, 512, x)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 2**31),
        rows=st.sampled_from([64, 128]),
        s=st.integers(1, 4),
        outlier=st.booleans(),
    )
    def test_hypothesis_fused(self, seed, rows, s, outlier):
        r = rng(seed)
        w = r.standard_normal((rows, 256)).astype(np.float32) * 0.02
        if outlier:
            w[r.integers(rows), r.integers(256)] = 0.5  # 25-sigma outlier
        x = r.standard_normal((256, s)).astype(np.float32)
        q = ref.quantize_matrix(w)
        got = np.asarray(
            dequant_matmul(q["codes"], q["sel"], q["d"], q["z"], x, rows=rows, cols=256)
        )
        want = ref.dequant_matmul_ref(q, rows, 256, x)
        np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)

    def test_quantization_actually_helps_vs_unrotated(self):
        # Rotation-domain coding beats raw-domain coding on outlier blocks
        # (the paper's central claim, checked at kernel level).
        r = rng(11)
        w = r.standard_normal((32, 256)).astype(np.float32) * 0.02
        for i in range(32):
            w[i, r.integers(256)] = 0.4 * (1 if i % 2 == 0 else -1)
        q = ref.quantize_matrix(w)
        w_rot = ref.dequantize_matrix_ref(q, 32, 256)
        err_rot = np.mean((w - w_rot) ** 2)
        # Raw-domain: same grid, no FWHT (encode on unrotated input).
        raw = w.copy()
        rot_back = []
        for row in raw:
            c = row - ref.f16_round(row.mean())
            d = max(float(ref.f16_round(np.float32(ref.DUAL_SCALE_STAR * c.std()))), 1e-8)
            a = np.abs(c)
            digit = np.where(a <= 0.5 * d, 0.0, np.sign(c))
            mag = np.where(a > 2 * d, 3 * d, d)
            rot_back.append(digit * mag + ref.f16_round(row.mean()))
        err_raw = np.mean((w - np.array(rot_back)) ** 2)
        assert err_rot < err_raw * 0.7, (err_rot, err_raw)
