"""L2 model tests: shapes, parity between dense and quantized forwards,
checkpoint container round-trip, and AOT lowering smoke."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import checkpoint, model


@pytest.fixture(scope="module")
def cfg():
    # Small config for test speed (2 layers; dims stay multiples of 256).
    c = model.config_tiny()
    c["n_layers"] = 2
    return c


@pytest.fixture(scope="module")
def params(cfg):
    return model.init_params(cfg, seed=7)


class TestForward:
    def test_logits_shape(self, cfg, params):
        toks = jnp.zeros(16, dtype=jnp.int32)
        logits = model.forward_fp32(toks, params, cfg)
        assert logits.shape == (16, cfg["vocab"])

    def test_causality(self, cfg, params):
        """Changing a later token must not affect earlier logits."""
        t1 = jnp.array([0, 5, 9, 12], dtype=jnp.int32)
        t2 = jnp.array([0, 5, 9, 200], dtype=jnp.int32)
        l1 = model.forward_fp32(t1, params, cfg)
        l2 = model.forward_fp32(t2, params, cfg)
        np.testing.assert_allclose(l1[:3], l2[:3], atol=1e-5)
        assert np.abs(np.asarray(l1[3] - l2[3])).max() > 1e-4

    def test_rope_position_dependence(self, cfg, params):
        """Same token at different positions gets different logits."""
        toks = jnp.array([0, 7, 7], dtype=jnp.int32)
        l = np.asarray(model.forward_fp32(toks, params, cfg))
        assert np.abs(l[1] - l[2]).max() > 1e-4

    def test_quantized_forward_tracks_fp32(self, cfg, params):
        qparams = model.quantize_params(params, cfg)
        toks = jnp.array([0, 3, 14, 15, 92, 65], dtype=jnp.int32)
        lf = np.asarray(model.forward_fp32(toks, params, cfg))
        lq = np.asarray(model.forward_itq3s(toks, qparams, cfg))
        rel = np.linalg.norm(lq - lf) / np.linalg.norm(lf)
        # 3-bit quantization: logits drift but stay correlated. (Top-1
        # agreement is only meaningful on a *trained* model — that is what
        # the Table-1 PPL harness measures; a random model's argmax is
        # noise.)
        assert rel < 0.8, rel
        corr = np.corrcoef(lf.ravel(), lq.ravel())[0, 1]
        assert corr > 0.6, corr

    def test_flatten_unflatten_roundtrip(self, cfg, params):
        flat = model.flatten_fp32(params)
        back = model.unflatten_fp32(cfg, flat)
        toks = jnp.array([0, 1, 2], dtype=jnp.int32)
        l1 = model.forward_fp32(toks, params, cfg)
        l2 = model.forward_fp32(toks, back, cfg)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2))

    def test_flat_entrypoint_matches(self, cfg, params):
        toks = jnp.array([0, 9, 8], dtype=jnp.int32)
        f = model.score_fp32(cfg)
        (l2,) = f(toks, *model.flatten_fp32(params))
        l1 = model.forward_fp32(toks, params, cfg)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2))


class TestCheckpoint:
    def test_iguf_roundtrip(self, cfg, params, tmp_path):
        path = str(tmp_path / "m.iguf")
        np_params = jax.tree.map(np.asarray, params)
        checkpoint.save_dense_checkpoint(path, np_params, cfg)
        cfg2, p2 = checkpoint.load_dense_checkpoint(path)
        assert cfg2 == cfg
        np.testing.assert_array_equal(p2["embed"], np_params["embed"])
        np.testing.assert_array_equal(
            p2["layers"][1]["w2"], np_params["layers"][1]["w2"]
        )

    def test_alignment(self, cfg, params, tmp_path):
        path = str(tmp_path / "m.iguf")
        checkpoint.save_dense_checkpoint(
            path, jax.tree.map(np.asarray, params), cfg
        )
        with open(path, "rb") as f:
            raw = f.read()
        assert raw[:4] == b"IGUF"


class TestAot:
    def test_fp32_lowering_produces_hlo_text(self, cfg):
        from compile.aot import to_hlo_text

        lowered = jax.jit(model.score_fp32(cfg)).lower(
            *model.fp32_arg_shapes(cfg, 16)
        )
        text = to_hlo_text(lowered)
        assert "HloModule" in text
        assert "f32[16,256]" in text  # (seq, vocab) logits

    def test_arg_shapes_counts(self, cfg):
        fp = model.fp32_arg_shapes(cfg, 8)
        q = model.itq3s_arg_shapes(cfg, 8)
        # tokens + embed + final_norm + L*(2 norms + 7 linears [x4 for quant])
        assert len(fp) == 3 + cfg["n_layers"] * 9
        assert len(q) == 3 + cfg["n_layers"] * (2 + 7 * 4)

    def test_manifest_order_matches_shapes(self, cfg):
        from compile.aot import input_order

        assert len(input_order(cfg, "fp32")) == len(model.fp32_arg_shapes(cfg, 8))
        assert len(input_order(cfg, "itq3s")) == len(model.itq3s_arg_shapes(cfg, 8))
