"""IGUF checkpoint writer/reader (python side of the container contract).

Binary layout must match ``rust/src/gguf/mod.rs`` byte-for-byte; the Rust
test-suite loads checkpoints written here (`rust/tests/artifacts.rs`).
"""

import json
import struct

import numpy as np

MAGIC = b"IGUF"
VERSION = 1
ALIGN = 64


def _entry_header(name: str, dtype: str, rows: int, cols: int, padded: int, dlen: int):
    nb = name.encode()
    db = dtype.encode()
    return (
        struct.pack("<I", len(nb)) + nb
        + struct.pack("<I", len(db)) + db
        + struct.pack("<QQQQ", rows, cols, padded, dlen)
    )


def write_iguf(path: str, meta: dict, tensors: list):
    """tensors: list of (name, np.ndarray f32 2-D or 1-D)."""
    buf = bytearray()
    buf += MAGIC
    buf += struct.pack("<I", VERSION)
    mb = json.dumps(meta, separators=(",", ":")).encode()
    buf += struct.pack("<Q", len(mb)) + mb
    buf += struct.pack("<Q", len(tensors))
    payloads = []
    for name, arr in tensors:
        arr = np.ascontiguousarray(arr, dtype=np.float32)
        if arr.ndim == 1:
            rows, cols = 1, arr.shape[0]
        else:
            rows, cols = arr.shape
        data = arr.tobytes()
        buf += _entry_header(name, "f32", rows, cols, cols, len(data))
        payloads.append(data)
    for data in payloads:
        while len(buf) % ALIGN != 0:
            buf += b"\x00"
        buf += data
    with open(path, "wb") as f:
        f.write(bytes(buf))


def read_iguf(path: str):
    """Returns (meta dict, {name: np.ndarray}). f32 tensors only."""
    with open(path, "rb") as f:
        raw = f.read()
    pos = 0

    def take(n):
        nonlocal pos
        s = raw[pos : pos + n]
        assert len(s) == n, "truncated IGUF"
        pos += n
        return s

    assert take(4) == MAGIC, "bad magic"
    (ver,) = struct.unpack("<I", take(4))
    assert ver == VERSION
    (mlen,) = struct.unpack("<Q", take(8))
    meta = json.loads(take(mlen))
    (n,) = struct.unpack("<Q", take(8))
    headers = []
    for _ in range(n):
        (nl,) = struct.unpack("<I", take(4))
        name = take(nl).decode()
        (dl,) = struct.unpack("<I", take(4))
        dtype = take(dl).decode()
        rows, cols, padded, dlen = struct.unpack("<QQQQ", take(32))
        headers.append((name, dtype, rows, cols, dlen))
    tensors = {}
    for name, dtype, rows, cols, dlen in headers:
        while pos % ALIGN != 0:
            pos += 1
        data = take(dlen)
        if dtype == "f32":
            arr = np.frombuffer(data, dtype=np.float32).reshape(rows, cols)
            tensors[name] = arr[0] if rows == 1 else arr
        else:
            tensors[name] = data  # opaque quant payload
    return meta, tensors


def save_dense_checkpoint(path: str, params: dict, cfg: dict):
    """Write a dense model in the layout rust `gguf::load_dense` expects."""
    tensors = [("embed", params["embed"])]
    for i, l in enumerate(params["layers"]):
        tensors.append((f"layers.{i}.attn_norm", l["attn_norm"]))
        for n in ["wq", "wk", "wv", "wo"]:
            tensors.append((f"layers.{i}.{n}", l[n]))
        tensors.append((f"layers.{i}.ffn_norm", l["ffn_norm"]))
        for n in ["w1", "w3", "w2"]:
            tensors.append((f"layers.{i}.{n}", l[n]))
    tensors.append(("final_norm", params["final_norm"]))
    meta = {"kind": "dense", "config": cfg}
    write_iguf(path, meta, tensors)


def load_dense_checkpoint(path: str):
    """Read a dense model back into the python params pytree."""
    meta, t = read_iguf(path)
    cfg = meta["config"]
    params = {"embed": t["embed"], "final_norm": t["final_norm"], "layers": []}
    for i in range(cfg["n_layers"]):
        layer = {}
        for n in ["attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w1", "w3", "w2"]:
            layer[n] = t[f"layers.{i}.{n}"]
        params["layers"].append(layer)
    return cfg, params
