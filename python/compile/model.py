"""Layer-2: the JAX transformer (build-time only).

A LLaMA-style decoder (RMSNorm, interleaved-pair RoPE, causal MHA,
SwiGLU, tied embeddings) in two flavors:

- ``score_fp32``: dense f32 weights — the training target and the FP16
  baseline artifact.
- ``score_itq3s``: every large linear is an ITQ3_S-packed buffer applied
  through the fused Pallas dequant+IFWHT+matmul kernel (L1) — the
  quantized-serving artifact. The packed planes are *runtime inputs*, so
  the Rust coordinator feeds weights quantized by its own encoder.

The math mirrors ``rust/src/model/native.rs`` op-for-op; the PJRT parity
integration test asserts logits agreement.

Flat argument order (the L3 contract, also emitted in
``artifacts/manifest.json``): ``tokens``, ``embed``, ``final_norm``, then
per layer: ``attn_norm``, [7 linears], ``ffn_norm`` where each linear is
one f32 array (fp32 flavor) or four arrays ``codes,sel,d,z`` (itq3s).
Linear order: wq wk wv wo w1 w3 w2.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.itq3s_matmul import dequant_matmul

LINEARS = ["wq", "wk", "wv", "wo", "w1", "w3", "w2"]


def config_tiny():
    return dict(
        vocab=256, dim=256, n_layers=4, n_heads=8, n_kv_heads=8,
        ffn=1024, max_seq=256, rope_theta=10_000.0, eps=1e-5,
    )


def linear_shapes(cfg):
    d, f = cfg["dim"], cfg["ffn"]
    return {
        "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "w1": (f, d), "w3": (f, d), "w2": (d, f),
    }


def rmsnorm(x, w, eps):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def rope(x, pos, n_heads, head_dim, theta):
    """Interleaved-pair RoPE for x: (S, dim); pos: (S,)."""
    s = x.shape[0]
    xh = x.reshape(s, n_heads, head_dim // 2, 2)
    i = jnp.arange(head_dim // 2, dtype=jnp.float32)
    freq = 1.0 / (theta ** (2.0 * i / head_dim))  # (hd/2,)
    ang = pos[:, None].astype(jnp.float32) * freq[None, :]  # (S, hd/2)
    sin = jnp.sin(ang)[:, None, :]
    cos = jnp.cos(ang)[:, None, :]
    a, b = xh[..., 0], xh[..., 1]
    rot = jnp.stack([a * cos - b * sin, a * sin + b * cos], axis=-1)
    return rot.reshape(s, n_heads * head_dim)


def attention(q, k, v, cfg):
    """Causal MHA for (S, dim) q/k/v."""
    s = q.shape[0]
    nh, hd = cfg["n_heads"], cfg["dim"] // cfg["n_heads"]
    qh = q.reshape(s, nh, hd).transpose(1, 0, 2)  # (nh, S, hd)
    kh = k.reshape(s, nh, hd).transpose(1, 0, 2)
    vh = v.reshape(s, nh, hd).transpose(1, 0, 2)
    scores = jnp.einsum("hqd,hkd->hqk", qh, kh) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask[None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hqk,hkd->hqd", probs, vh)  # (nh, S, hd)
    return out.transpose(1, 0, 2).reshape(s, nh * hd)


def _block(x, pos, layer, apply_linear, cfg):
    """One decoder layer; `apply_linear(name, h)` abstracts the weight
    representation (dense f32 vs fused ITQ3_S kernel)."""
    h = rmsnorm(x, layer["attn_norm"], cfg["eps"])
    q = apply_linear(layer, "wq", h)
    k = apply_linear(layer, "wk", h)
    v = apply_linear(layer, "wv", h)
    hd = cfg["dim"] // cfg["n_heads"]
    q = rope(q, pos, cfg["n_heads"], hd, cfg["rope_theta"])
    k = rope(k, pos, cfg["n_heads"], hd, cfg["rope_theta"])
    x = x + apply_linear(layer, "wo", attention(q, k, v, cfg))
    h = rmsnorm(x, layer["ffn_norm"], cfg["eps"])
    gate = apply_linear(layer, "w1", h)
    up = apply_linear(layer, "w3", h)
    x = x + apply_linear(layer, "w2", jax.nn.silu(gate) * up)
    return x


def _forward(tokens, params, apply_linear, cfg):
    """tokens: (S,) int32 -> logits (S, vocab)."""
    s = tokens.shape[0]
    pos = jnp.arange(s)
    x = params["embed"][tokens]  # (S, dim)
    for layer in params["layers"]:
        x = _block(x, pos, layer, apply_linear, cfg)
    h = rmsnorm(x, params["final_norm"], cfg["eps"])
    return h @ params["embed"].T  # tied LM head


def _dense_apply(layer, name, h):
    return h @ layer[name].T


def forward_fp32(tokens, params, cfg):
    return _forward(tokens, params, _dense_apply, cfg)


def _make_quant_apply(cfg):
    shapes = linear_shapes(cfg)

    def apply(layer, name, h):
        rows, cols = shapes[name]
        q = layer[name]
        # Fused kernel computes W @ x for x (cols, S); h is (S, cols).
        y = dequant_matmul(
            q["codes"], q["sel"], q["d"], q["z"], h.T, rows=rows, cols=cols
        )
        return y.T

    return apply


def forward_itq3s(tokens, params, cfg):
    return _forward(tokens, params, _make_quant_apply(cfg), cfg)


# ---------------------------------------------------------------------
# Flat-argument entry points for AOT lowering (L3 feeds buffers in this
# exact order; see module docstring).
# ---------------------------------------------------------------------

def flatten_fp32(params):
    out = [params["embed"], params["final_norm"]]
    for l in params["layers"]:
        out.append(l["attn_norm"])
        for n in LINEARS:
            out.append(l[n])
        out.append(l["ffn_norm"])
    return out


def unflatten_fp32(cfg, args):
    args = list(args)
    params = {"embed": args.pop(0), "final_norm": args.pop(0), "layers": []}
    for _ in range(cfg["n_layers"]):
        layer = {"attn_norm": args.pop(0)}
        for n in LINEARS:
            layer[n] = args.pop(0)
        layer["ffn_norm"] = args.pop(0)
        params["layers"].append(layer)
    assert not args
    return params


def flatten_itq3s(params):
    out = [params["embed"], params["final_norm"]]
    for l in params["layers"]:
        out.append(l["attn_norm"])
        for n in LINEARS:
            q = l[n]
            out.extend([q["codes"], q["sel"], q["d"], q["z"]])
        out.append(l["ffn_norm"])
    return out


def unflatten_itq3s(cfg, args):
    args = list(args)
    params = {"embed": args.pop(0), "final_norm": args.pop(0), "layers": []}
    for _ in range(cfg["n_layers"]):
        layer = {"attn_norm": args.pop(0)}
        for n in LINEARS:
            layer[n] = {
                "codes": args.pop(0), "sel": args.pop(0),
                "d": args.pop(0), "z": args.pop(0),
            }
        layer["ffn_norm"] = args.pop(0)
        params["layers"].append(layer)
    assert not args
    return params


def score_fp32(cfg):
    """Returns f(tokens, *flat_params) -> (S, vocab) logits."""

    def f(tokens, *flat):
        return (forward_fp32(tokens, unflatten_fp32(cfg, flat), cfg),)

    return f


def score_itq3s(cfg):
    def f(tokens, *flat):
        return (forward_itq3s(tokens, unflatten_itq3s(cfg, flat), cfg),)

    return f


def fp32_arg_shapes(cfg, seq):
    """ShapeDtypeStructs for lowering the fp32 artifact."""
    d, f, v = cfg["dim"], cfg["ffn"], cfg["vocab"]
    sds = jax.ShapeDtypeStruct
    args = [sds((seq,), jnp.int32), sds((v, d), jnp.float32), sds((d,), jnp.float32)]
    shapes = linear_shapes(cfg)
    for _ in range(cfg["n_layers"]):
        args.append(sds((d,), jnp.float32))
        for n in LINEARS:
            args.append(sds(shapes[n], jnp.float32))
        args.append(sds((d,), jnp.float32))
    return args


def itq3s_arg_shapes(cfg, seq):
    d, v = cfg["dim"], cfg["vocab"]
    sds = jax.ShapeDtypeStruct
    args = [sds((seq,), jnp.int32), sds((v, d), jnp.float32), sds((d,), jnp.float32)]
    shapes = linear_shapes(cfg)
    for _ in range(cfg["n_layers"]):
        args.append(sds((d,), jnp.float32))
        for n in LINEARS:
            rows, cols = shapes[n]
            nb = cols // 256
            args.append(sds((rows, nb * 16), jnp.uint32))
            args.append(sds((rows, nb * 8), jnp.uint32))
            args.append(sds((rows, nb), jnp.float32))
            args.append(sds((rows, nb), jnp.float32))
        args.append(sds((d,), jnp.float32))
    return args


def init_params(cfg, seed=0, tail_dof=None):
    """Random dense initialization.

    ``tail_dof``: None for Gaussian; a float t-distribution dof induces the
    heavy-tailed, outlier-bearing weight statistics that large trained
    LLMs exhibit (paper §1; kurtosis 4-20 in practice). A tiny model
    trained a few hundred steps from Gaussian init stays near-Gaussian,
    so the Table-1 regime is induced at init — the documented
    substitution (DESIGN.md §6) that preserves the phenomenon ITQ3_S
    targets. Training proceeds normally from this init and the tails
    persist.
    """
    rng = np.random.default_rng(seed)
    shapes = linear_shapes(cfg)

    def mat(rows, cols):
        if tail_dof is None:
            w = rng.standard_normal((rows, cols))
        else:
            w = rng.standard_t(tail_dof, size=(rows, cols))
            w /= np.sqrt(tail_dof / (tail_dof - 2.0))  # unit variance
        return (w / np.sqrt(cols)).astype(np.float32)

    layers = []
    for _ in range(cfg["n_layers"]):
        layer = {"attn_norm": np.ones(cfg["dim"], np.float32),
                 "ffn_norm": np.ones(cfg["dim"], np.float32)}
        for n in LINEARS:
            layer[n] = mat(*shapes[n])
        layers.append(layer)
    return {
        "embed": mat(cfg["vocab"], cfg["dim"]),
        "final_norm": np.ones(cfg["dim"], np.float32),
        "layers": layers,
    }


def quantize_params(params, cfg):
    """ITQ3_S-quantize all linears (python-side, for tests and AOT
    examples; the serving path quantizes in Rust)."""
    from .kernels import ref

    out = {"embed": params["embed"], "final_norm": params["final_norm"], "layers": []}
    for l in params["layers"]:
        ql = {"attn_norm": l["attn_norm"], "ffn_norm": l["ffn_norm"]}
        for n in LINEARS:
            ql[n] = ref.quantize_matrix(np.asarray(l[n]))
        out["layers"].append(ql)
    return out
