"""AOT lowering: JAX model -> HLO text artifacts for the Rust runtime.

HLO *text* is the interchange format (NOT serialized HloModuleProto):
jax >= 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to --out, default ../artifacts):
- model_fp32.hlo.txt    score(tokens, *dense weights) -> logits
- model_itq3s.hlo.txt   score(tokens, *packed ITQ3_S buffers) -> logits
                        (fused Pallas dequant+IFWHT+matmul in-graph)
- manifest.json         seq length, config, exact input ordering

Usage: python -m compile.aot [--seq 128] [--out DIR]
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def input_order(cfg, kind: str):
    """Human/machine-readable input ordering for the manifest."""
    names = ["tokens", "embed", "final_norm"]
    for i in range(cfg["n_layers"]):
        names.append(f"layers.{i}.attn_norm")
        for n in model.LINEARS:
            if kind == "fp32":
                names.append(f"layers.{i}.{n}")
            else:
                names.extend(
                    f"layers.{i}.{n}.{part}" for part in ["codes", "sel", "d", "z"]
                )
        names.append(f"layers.{i}.ffn_norm")
    return names


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = model.config_tiny()

    print("lowering fp32 artifact...", flush=True)
    fp32 = jax.jit(model.score_fp32(cfg)).lower(*model.fp32_arg_shapes(cfg, args.seq))
    fp32_path = os.path.join(args.out, "model_fp32.hlo.txt")
    with open(fp32_path, "w") as f:
        f.write(to_hlo_text(fp32))
    print(f"  wrote {fp32_path}", flush=True)

    print("lowering itq3s artifact (fused Pallas kernel in-graph)...", flush=True)
    q = jax.jit(model.score_itq3s(cfg)).lower(*model.itq3s_arg_shapes(cfg, args.seq))
    q_path = os.path.join(args.out, "model_itq3s.hlo.txt")
    with open(q_path, "w") as f:
        f.write(to_hlo_text(q))
    print(f"  wrote {q_path}", flush=True)

    manifest = {
        "seq": args.seq,
        "config": cfg,
        "artifacts": {
            "fp32": {"file": "model_fp32.hlo.txt", "inputs": input_order(cfg, "fp32")},
            "itq3_s": {
                "file": "model_itq3s.hlo.txt",
                "inputs": input_order(cfg, "itq3s"),
            },
        },
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("wrote manifest.json", flush=True)


if __name__ == "__main__":
    main()
