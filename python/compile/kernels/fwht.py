"""Layer-1 Pallas kernel: blocked Fast Walsh-Hadamard Transform.

The TPU rethink of the paper's CUDA shared-memory butterfly (Listing 2):
instead of per-thread index arithmetic with ``__syncthreads`` between the
8 stages, the whole 256-wide block lives in VMEM and each butterfly stage
is a reshape + add/sub over VPU lanes. ``interpret=True`` everywhere —
the CPU PJRT plugin cannot execute Mosaic custom-calls (see
DESIGN.md §Hardware-Adaptation for the VMEM/MXU analysis).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256


def _fwht_last_axis(x, n):
    """Normalized FWHT along the last axis (size n), butterfly stages."""
    shape = x.shape
    y = x
    m = 1
    while m < n:
        y = y.reshape(*shape[:-1], n // (2 * m), 2, m)
        top = y[..., 0, :] + y[..., 1, :]
        bot = y[..., 0, :] - y[..., 1, :]
        y = jnp.stack([top, bot], axis=-2).reshape(*shape)
        m *= 2
    return y * (1.0 / jnp.sqrt(jnp.float32(n)))


def _fwht_kernel(x_ref, o_ref, *, block):
    rows = x_ref[...]  # (tile_rows, nblocks*block)
    t, c = rows.shape
    wb = rows.reshape(t, c // block, block)
    o_ref[...] = _fwht_last_axis(wb, block).reshape(t, c)


@functools.partial(jax.jit, static_argnames=("block",))
def fwht_blocked(x, block: int = BLOCK):
    """Apply the normalized FWHT to each contiguous `block` of the last
    axis of a 2-D array (rows are independent)."""
    rows, cols = x.shape
    assert cols % block == 0, f"cols {cols} % block {block}"
    tile = min(rows, 64)
    assert rows % tile == 0
    return pl.pallas_call(
        functools.partial(_fwht_kernel, block=block),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        grid=(rows // tile,),
        in_specs=[pl.BlockSpec((tile, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, cols), lambda i: (i, 0)),
        interpret=True,
    )(x.astype(jnp.float32))


# The transform is involutory; expose the paper's name for call sites.
ifwht_blocked = fwht_blocked
