"""Layer-1 Pallas kernel: fused ITQ3_S dequantize + inverse-FWHT + matmul.

This is the paper's core kernel (Alg 2, `load_tiles_itq3_s` + MMQ) mapped
to TPU idioms:

- a (TILE_R x cols) tile of packed quants is staged into VMEM by the
  BlockSpec (the analog of the CUDA global->shared load),
- 3-bit codes are unpacked with vectorized shift/mask int32 ops (the
  "single 32-bit load + bitfield extraction" of §4.2),
- the 256-point inverse FWHT runs as 8 reshape/± butterfly stages over
  VPU lanes (the analog of the shared-memory butterfly with
  __syncthreads),
- the reconstructed tile immediately feeds the matmul (MXU), so rotated
  weights never leave on-chip memory — the fusion that gives the paper
  its "no off-chip traffic penalty" property.

`interpret=True` (CPU correctness); the VMEM budget of the tile is
analyzed in DESIGN.md §Hardware-Adaptation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fwht import _fwht_last_axis

BLOCK = 256


def _unpack_tile(codes, sel, d, z, cols):
    """Vectorized decode of the packed planes to rotated-domain values.

    codes: u32 (R, nb*16), sel: u32 (R, nb*8), d/z: f32 (R, nb).
    Returns f32 (R, cols).
    """
    r = codes.shape[0]
    nb = cols // BLOCK
    # 2-bit codes: expand each u32 word into its 16 fields.
    shifts = (2 * jnp.arange(16, dtype=jnp.uint32))[None, None, :]
    c = (codes[:, :, None] >> shifts) & jnp.uint32(3)  # (R, nb*16, 16)
    digit = c.astype(jnp.float32).reshape(r, nb, BLOCK) - 1.0
    # selector bits: expand each u32 word into its 32 bits.
    sshifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    s = (sel[:, :, None] >> sshifts) & jnp.uint32(1)  # (R, nb*8, 32)
    sbit = s.astype(jnp.float32).reshape(r, nb, BLOCK)
    mag = d[:, :, None] * (1.0 + 2.0 * sbit)  # d or 3d
    return (digit * mag + z[:, :, None]).reshape(r, cols)


def _fused_kernel(codes_ref, sel_ref, d_ref, z_ref, x_ref, o_ref, *, cols):
    rot = _unpack_tile(codes_ref[...], sel_ref[...], d_ref[...], z_ref[...], cols)
    # In-place inverse rotation in "VMEM" (H is involutory).
    r = rot.shape[0]
    w = _fwht_last_axis(rot.reshape(r, cols // BLOCK, BLOCK), BLOCK).reshape(r, cols)
    # Fused matmul: the dequantized tile feeds the MXU directly.
    o_ref[...] = w @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("rows", "cols"))
def dequant_matmul(codes, sel, d, z, x, *, rows: int, cols: int):
    """Fused `W_hat @ x` for an ITQ3_S-packed `(rows, cols)` matrix and
    activations `x: (cols, s)`. Returns `(rows, s)` f32."""
    s = x.shape[1]
    tile = 64 if rows % 64 == 0 else rows
    assert rows % tile == 0
    nb = cols // BLOCK
    return pl.pallas_call(
        functools.partial(_fused_kernel, cols=cols),
        out_shape=jax.ShapeDtypeStruct((rows, s), jnp.float32),
        grid=(rows // tile,),
        in_specs=[
            pl.BlockSpec((tile, nb * 16), lambda i: (i, 0)),
            pl.BlockSpec((tile, nb * 8), lambda i: (i, 0)),
            pl.BlockSpec((tile, nb), lambda i: (i, 0)),
            pl.BlockSpec((tile, nb), lambda i: (i, 0)),
            pl.BlockSpec((cols, s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, s), lambda i: (i, 0)),
        interpret=True,
    )(codes, sel, d, z, x.astype(jnp.float32))


def dequantize(codes, sel, d, z, *, rows: int, cols: int):
    """Standalone dequantization (Alg 2 without the matmul): identity
    activations through the fused kernel."""
    eye = jnp.eye(cols, dtype=jnp.float32)
    return dequant_matmul(codes, sel, d, z, eye, rows=rows, cols=cols)
