"""Layer-1 Pallas kernels (build-time only; never on the request path)."""
