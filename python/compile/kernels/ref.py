"""Pure-jnp/numpy reference oracles for the Pallas kernels.

Everything here is the *specification*: the Pallas kernels in
``fwht.py``/``itq3s_matmul.py`` must match these references bit-for-bit
(integer unpacking) or to float tolerance (transforms, matmuls); pytest
enforces it (``python/tests/test_kernels.py``).

The packed layout is the contract with the Rust encoder
(``rust/src/quant/itq3s.rs`` / ``packing.rs``):

- base plane  u32[rows, nblocks*16]: code for column t of block b sits at
  bits ``2*(t%16)`` of word ``b*16 + t//16`` (LSB-first, little-endian).
- selector    u32[rows, nblocks*8]:  bit for column t of block b sits at
  bit ``t%32`` of word ``b*8 + t//32``.
- d, z        f32[rows, nblocks] (f16-rounded values, widened to f32).

Grid: value = (code-1) * d * (1 + 2*sel) + z, then a 256-point inverse
FWHT per block returns the weight to the original domain.
"""

import numpy as np

BLOCK = 256
# MSE-optimal dual-ternary step for N(0,1) (see rust quant::ternary).
DUAL_SCALE_STAR = 0.5682


def fwht_ref(x: np.ndarray) -> np.ndarray:
    """Normalized FWHT along the last axis via the dense H matrix (oracle)."""
    n = x.shape[-1]
    assert n & (n - 1) == 0
    i = np.arange(n)
    h = np.where(np.bitwise_count(i[:, None] & i[None, :]) % 2 == 0, 1.0, -1.0)
    h = (h / np.sqrt(n)).astype(np.float32)
    return (x.astype(np.float32) @ h.T).astype(np.float32)


def fwht_butterfly(x: np.ndarray) -> np.ndarray:
    """Normalized FWHT along the last axis via butterflies (fast reference,
    same stage order as the Rust and Pallas implementations)."""
    n = x.shape[-1]
    y = x.astype(np.float32).copy()
    m = 1
    while m < n:
        y = y.reshape(*y.shape[:-1], n // (2 * m), 2, m)
        top = y[..., 0, :] + y[..., 1, :]
        bot = y[..., 0, :] - y[..., 1, :]
        y = np.stack([top, bot], axis=-2).reshape(*top.shape[:-2], -1, n)
        y = y.reshape(*y.shape[:-2], n)
        m *= 2
    return y / np.float32(np.sqrt(n))


def f16_round(x: np.ndarray) -> np.ndarray:
    """Round-trip through IEEE binary16 (numpy uses RNE, same as Rust)."""
    return np.asarray(x, dtype=np.float32).astype(np.float16).astype(np.float32)


def encode_block(w: np.ndarray):
    """ITQ3_S-encode one 256-vector. Returns (codes u8[256] in {0,1,2},
    sel u8[256] in {0,1}, d f32, z f32). Mirrors rust Itq3S::quantize_block."""
    assert w.shape == (BLOCK,)
    rot = fwht_butterfly(w[None, :])[0]
    z = float(f16_round(rot.mean()))
    c = rot - z
    d = float(f16_round(np.float32(DUAL_SCALE_STAR * c.std())))
    d = max(d, 1e-8)
    a = np.abs(c)
    zero = a <= 0.5 * d
    coarse = a > 2.0 * d
    digit = np.where(zero, 0, np.sign(c)).astype(np.int8)
    codes = (digit + 1).astype(np.uint8)
    sel = (coarse & ~zero).astype(np.uint8)
    return codes, sel, np.float32(d), np.float32(z)


def pack_planes(codes: np.ndarray, sel: np.ndarray):
    """Pack per-block codes/sel (shape (nblocks, 256)) into the u32 planes."""
    nb = codes.shape[0]
    cw = np.zeros((nb, 16), dtype=np.uint32)
    sw = np.zeros((nb, 8), dtype=np.uint32)
    for t in range(BLOCK):
        cw[:, t // 16] |= codes[:, t].astype(np.uint32) << np.uint32(2 * (t % 16))
        sw[:, t // 32] |= sel[:, t].astype(np.uint32) << np.uint32(t % 32)
    return cw.reshape(-1), sw.reshape(-1)


def quantize_matrix(w: np.ndarray):
    """Quantize a (rows, cols) matrix to the ITQ3_S input-array layout.

    Returns dict with codes u32[rows, nb*16], sel u32[rows, nb*8],
    d f32[rows, nb], z f32[rows, nb].
    """
    rows, cols = w.shape
    assert cols % BLOCK == 0
    nb = cols // BLOCK
    codes = np.zeros((rows, nb * 16), dtype=np.uint32)
    sel = np.zeros((rows, nb * 8), dtype=np.uint32)
    d = np.zeros((rows, nb), dtype=np.float32)
    z = np.zeros((rows, nb), dtype=np.float32)
    for r in range(rows):
        cs = np.zeros((nb, BLOCK), dtype=np.uint8)
        ss = np.zeros((nb, BLOCK), dtype=np.uint8)
        for b in range(nb):
            c, s, dd, zz = encode_block(w[r, b * BLOCK : (b + 1) * BLOCK])
            cs[b], ss[b] = c, s
            d[r, b], z[r, b] = dd, zz
        codes[r], sel[r] = pack_planes(cs, ss)
    return {"codes": codes, "sel": sel, "d": d, "z": z}


def unpack_ref(q: dict, rows: int, cols: int) -> np.ndarray:
    """Reference decode of the packed planes to rotated-domain values."""
    nb = cols // BLOCK
    t = np.arange(cols)
    b = t // BLOCK
    ti = t % BLOCK
    word = b * 16 + ti // 16
    shift = (2 * (ti % 16)).astype(np.uint32)
    code = (q["codes"][:, word] >> shift[None, :]) & 3
    digit = code.astype(np.float32) - 1.0
    sword = b * 8 + ti // 32
    sshift = (ti % 32).astype(np.uint32)
    sbit = ((q["sel"][:, sword] >> sshift[None, :]) & 1).astype(np.float32)
    dcol = np.repeat(q["d"], BLOCK, axis=1)
    zcol = np.repeat(q["z"], BLOCK, axis=1)
    return (digit * dcol * (1.0 + 2.0 * sbit) + zcol).astype(np.float32)


def dequantize_matrix_ref(q: dict, rows: int, cols: int) -> np.ndarray:
    """Full reference dequantization back to the original weight domain."""
    rot = unpack_ref(q, rows, cols)
    wb = rot.reshape(rows, cols // BLOCK, BLOCK)
    return fwht_butterfly(wb).reshape(rows, cols)


def dequant_matmul_ref(q: dict, rows: int, cols: int, x: np.ndarray) -> np.ndarray:
    """Reference fused op: W_hat @ x for x of shape (cols, S)."""
    w = dequantize_matrix_ref(q, rows, cols)
    return (w @ x).astype(np.float32)
