"""Build-time training of the tiny byte LM (the LLaMA-checkpoint stand-in).

Trains the L2 model on the Rust-generated synthetic corpus
(`artifacts/corpus/train.txt`, written by `itq3s gen-corpus`) with a
hand-rolled Adam (optax is not in the offline image). Emits:

- `artifacts/model_fp32.iguf` — the dense checkpoint (IGUF container,
  loaded by both the Rust quantizer and `aot.py`),
- `artifacts/train_log.json` — loss curve + final PPL (the E2E record
  referenced by EXPERIMENTS.md).

Usage: python -m compile.train [--steps N] [--out DIR]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import checkpoint, model


def load_corpus(path: str, fallback_bytes: int = 300_000) -> bytes:
    if os.path.exists(path):
        with open(path, "rb") as f:
            return f.read()
    raise SystemExit(
        f"corpus not found at {path}; run `cargo run --release -- gen-corpus` first"
    )


def batches(data: np.ndarray, batch: int, seq: int, rng: np.random.Generator):
    """Random windows; target is the next byte. BOS (0) prepended."""
    while True:
        idx = rng.integers(0, len(data) - seq - 1, size=batch)
        x = np.zeros((batch, seq), dtype=np.int32)
        y = np.zeros((batch, seq), dtype=np.int32)
        for i, j in enumerate(idx):
            x[i, 0] = 0  # BOS
            x[i, 1:] = data[j : j + seq - 1]
            y[i] = data[j : j + seq]
        yield jnp.asarray(x), jnp.asarray(y)


def make_loss(cfg):
    def loss_fn(params, x, y):
        # vmap the single-sequence forward over the batch.
        logits = jax.vmap(lambda t: model.forward_fp32(t, params, cfg))(x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[..., None], axis=-1).squeeze(-1)
        return nll.mean()

    return loss_fn


def adam_update(grads, m, v, step, lr, b1=0.9, b2=0.95, eps=1e-8):
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    bc1 = 1 - b1 ** step
    bc2 = 1 - b2 ** step
    upd = jax.tree.map(lambda mm, vv: lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps), m, v)
    return upd, m, v


def clip_by_global_norm(grads, max_norm=1.0):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=260)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=96)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--tail-dof", type=float, default=4.0,
        help="student-t dof for heavy-tailed init (0 = Gaussian); see init_params",
    )
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--corpus", default="../artifacts/corpus/train.txt")
    args = ap.parse_args()

    cfg = model.config_tiny()
    tail = args.tail_dof if args.tail_dof > 0 else None
    params = model.init_params(cfg, seed=args.seed, tail_dof=tail)
    params = jax.tree.map(jnp.asarray, params)

    data = np.frombuffer(load_corpus(args.corpus), dtype=np.uint8).astype(np.int32)
    print(f"corpus: {len(data)} bytes; model: ~{6.6:.1f}M params", flush=True)

    loss_fn = make_loss(cfg)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    rng = np.random.default_rng(args.seed)
    gen = batches(data, args.batch, args.seq, rng)

    log = []
    t0 = time.time()
    warmup = max(10, args.steps // 20)
    for step in range(1, args.steps + 1):
        x, y = next(gen)
        loss, grads = grad_fn(params, x, y)
        grads, gn = clip_by_global_norm(grads)
        # Linear warmup, cosine decay.
        frac = step / args.steps
        lr = args.lr * min(1.0, step / warmup) * 0.5 * (1 + np.cos(np.pi * frac))
        upd, m, v = adam_update(grads, m, v, step, lr)
        params = jax.tree.map(lambda p, u: p - u, params, upd)
        if step % 10 == 0 or step == 1:
            el = time.time() - t0
            print(
                f"step {step:4d}  loss {float(loss):.4f}  ppl {float(jnp.exp(loss)):8.2f}"
                f"  gnorm {float(gn):6.2f}  lr {lr:.2e}  {el:6.1f}s",
                flush=True,
            )
        log.append({"step": step, "loss": float(loss)})

    os.makedirs(args.out, exist_ok=True)
    np_params = jax.tree.map(np.asarray, params)
    ckpt = os.path.join(args.out, "model_fp32.iguf")
    checkpoint.save_dense_checkpoint(ckpt, np_params, cfg)
    with open(os.path.join(args.out, "train_log.json"), "w") as f:
        json.dump(
            {
                "config": cfg,
                "steps": args.steps,
                "batch": args.batch,
                "seq": args.seq,
                "final_loss": log[-1]["loss"],
                "final_ppl": float(np.exp(log[-1]["loss"])),
                "wall_seconds": time.time() - t0,
                "curve": log,
            },
            f,
            indent=1,
        )
    print(f"saved {ckpt}; final loss {log[-1]['loss']:.4f}", flush=True)


if __name__ == "__main__":
    main()
