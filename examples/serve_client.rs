//! Serving demo: start the coordinator on the quantized checkpoint,
//! drive it as a client over TCP (streaming tokens), print stats.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example serve_client
//! # or against an external server started with:
//! #   itq3s serve --model artifacts/model_itq3s.iguf --addr 127.0.0.1:8090
//! cargo run --release --example serve_client -- 127.0.0.1:8090
//! ```

use itq3s::coordinator::CoordinatorConfig;
use itq3s::model::NativeEngine;
use itq3s::server::{self, Client};
use itq3s::util::json::Json;

fn main() -> anyhow::Result<()> {
    let external: Option<String> = std::env::args().nth(1);

    let (addr, handle) = match external {
        Some(a) => (a, None),
        None => {
            let qm = itq3s::gguf::load_quantized(std::path::Path::new(
                "artifacts/model_itq3s.iguf",
            ))?;
            println!("loaded itq3_s model ({} of packed linears)",
                itq3s::util::human_bytes(qm.linear_nbytes() as u64));
            let (a, h) = server::spawn_ephemeral(
                Box::new(NativeEngine::quantized(qm)),
                CoordinatorConfig {
                    max_batch: 4,
                    kv_budget_bytes: 128 << 20,
                    prefill_chunk: 32,
                    ..Default::default()
                },
            )?;
            (a.to_string(), Some(h))
        }
    };

    let mut c = Client::connect(&addr)?;
    for prompt in [
        "the archive of the glass city was ",
        "in the year 8",
        "quick update: rowan ",
    ] {
        print!("[prompt] {prompt:?} -> ");
        c.send(&Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str(prompt)),
            ("max_tokens", Json::num(40.0)),
            ("stop_at_sentence", Json::Bool(true)),
        ]))?;
        loop {
            let msg = c.recv()?;
            if let Some(t) = msg.get("token").and_then(|t| t.as_str()) {
                print!("{t}");
                use std::io::Write;
                std::io::stdout().flush()?;
            } else if msg.get("done").is_some() {
                println!(
                    "   [{} tok, ttft {:.0} ms, total {:.0} ms]",
                    msg.get("gen_tokens").and_then(|v| v.as_u64()).unwrap_or(0),
                    msg.get("ttft_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    msg.get("total_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
                );
                break;
            }
        }
    }

    c.send(&Json::obj(vec![("op", Json::str("stats"))]))?;
    println!("\nserver stats: {}", c.recv()?);

    if let Some(h) = handle {
        c.send(&Json::obj(vec![("op", Json::str("shutdown"))]))?;
        let _ = c.recv();
        h.join().unwrap()?;
    }
    Ok(())
}
