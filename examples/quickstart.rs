//! Quickstart: quantize a block of weights, reconstruct it, and see the
//! rotation-domain advantage — the library's core loop in 60 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use itq3s::quant::{format_by_name, matmul::QuantizedLinear, QuantizedMatrix};
use itq3s::tensor::Tensor;
use itq3s::util::{stats, XorShift};

fn main() {
    // 1. Heavy-tailed weights with planted outliers — the regime the
    //    paper targets (§1).
    let mut rng = XorShift::new(7);
    let mut w = Tensor::zeros(vec![64, 1024]);
    for x in w.data_mut() {
        *x = (rng.next_student_t(4.0) as f32) * 0.02;
    }
    for i in (0..w.len()).step_by(333) {
        w.data_mut()[i] = 0.45 * rng.next_sign(); // ~22-sigma outliers
    }
    println!(
        "weights: 64x1024, sigma={:.4}, kurtosis={:.1}, |w|max={:.2}",
        stats::stddev(w.data()),
        stats::kurtosis(w.data()),
        stats::linf(w.data())
    );

    // 2. Quantize with ITQ3_S (FWHT rotation + 3-bit interleaved ternary)
    //    and with the unrotated 3-bit baseline.
    for name in ["itq3_s", "iq3_s", "q4_k_m", "q8_0"] {
        let fmt = format_by_name(name).unwrap();
        let q = QuantizedMatrix::quantize(fmt.clone(), &w);
        let recon = q.dequantize();
        println!(
            "  {name:<8} {:>6.3} b/w  {:>8} bytes  rel-err {:.4}",
            fmt.bits_per_weight(),
            q.nbytes(),
            stats::rel_l2_err(w.data(), recon.data()),
        );
    }

    // 3. The serving primitive: fused dequant matvec (activations rotated
    //    once; weights stay packed — the paper's Alg 2 on CPU).
    let lin = QuantizedLinear::new(format_by_name("itq3_s").unwrap(), &w);
    let x: Vec<f32> = (0..1024).map(|_| rng.next_f32() - 0.5).collect();
    let mut y = vec![0.0f32; 64];
    lin.matvec(&x, &mut y);
    let mut y_ref = vec![0.0f32; 64];
    itq3s::tensor::matvec_accum(&w, &x, &mut y_ref);
    println!(
        "matvec through packed weights: output rel-err {:.4}",
        stats::rel_l2_err(&y_ref, &y)
    );

    // 4. Paper §7.3: what this buys at LLaMA-3 70B scale.
    let cfg70 = itq3s::model::ModelConfig::llama3_70b();
    let gib = itq3s::model::memory::weight_bytes(&cfg70, 3.125) / itq3s::model::memory::GIB;
    let ctx =
        itq3s::model::memory::max_context(&cfg70, 3.125, 32.0 * itq3s::model::memory::GIB);
    println!(
        "LLaMA-3 70B @ 3.125 b/w: {gib:.1} GiB weights, ~{ctx} tokens of KV headroom in 32 GiB"
    );
}
