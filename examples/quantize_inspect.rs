//! Inspect the trained checkpoint: weight statistics, the Theorem 1
//! Gaussianization effect, Corollary 1 outlier suppression, the
//! Theorem 2 bound, and per-format reconstruction errors — the paper's
//! §3 analysis on real (trained, not synthetic) weights.
//!
//! ```bash
//! make artifacts && cargo run --release --example quantize_inspect
//! ```

use itq3s::quant::{format_by_name, QuantizedMatrix, TABLE1_FORMATS};
use itq3s::util::stats;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let ckpt = Path::new("artifacts/model_fp32.iguf");
    if !ckpt.exists() {
        eprintln!("artifacts/model_fp32.iguf missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let dense = itq3s::gguf::load_dense(ckpt)?;
    itq3s::bench::tables::inspect_model(&dense);

    println!("\n=== per-tensor reconstruction error (layer 0) ===");
    let l = &dense.layers[0];
    for (name, t) in [("wq", &l.wq), ("wo", &l.wo), ("w1", &l.w1), ("w2", &l.w2)] {
        print!("  {name:<4}");
        for fmt_name in TABLE1_FORMATS {
            let fmt = format_by_name(fmt_name).unwrap();
            let q = QuantizedMatrix::quantize(fmt, t);
            let rel = stats::rel_l2_err(t.data(), q.dequantize().data());
            print!("  {fmt_name}={rel:.4}");
        }
        println!();
    }

    println!("\n=== rotation gain per layer (MSE_unrotated / MSE_rotated, 3-bit) ===");
    for (i, l) in dense.layers.iter().enumerate() {
        let gain = itq3s::quant::error::rotation_gain(l.w2.data(), 256);
        println!("  layer {i} w2: {gain:.2}x");
    }
    Ok(())
}
