//! End-to-end validation driver (the EXPERIMENTS.md §E2E record):
//! trained checkpoint -> Rust quantizer -> coordinator + TCP server ->
//! concurrent batched clients -> throughput/latency/PPL report.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

fn main() -> anyhow::Result<()> {
    itq3s::bench::tables::e2e("artifacts")
}
